#!/usr/bin/env bash
# Execute every fenced ```python block in docs/*.md so the snippets
# cannot rot. Blocks within one file are concatenated top-to-bottom and
# run as a single script (later snippets may use earlier definitions),
# under the tier-1 PYTHONPATH. Wired into scripts/tier1.sh (full mode).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python - "$@" <<'EOF'
import os
import pathlib
import re
import subprocess
import sys
import tempfile

docs = sorted(pathlib.Path("docs").glob("*.md"))
if not docs:
    sys.exit("docs_check: no docs/*.md found")

fence = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)
failed = False
for doc in docs:
    blocks = fence.findall(doc.read_text())
    if not blocks:
        print(f"  {doc}: no python blocks")
        continue
    script = "\n\n".join(b.strip("\n") for b in blocks) + "\n"
    with tempfile.NamedTemporaryFile(
        "w", suffix=f"_{doc.stem}.py", delete=False
    ) as f:
        f.write(script)
        path = f.name
    try:
        proc = subprocess.run([sys.executable, path])
    finally:
        os.unlink(path)
    status = "ok" if proc.returncode == 0 else "FAILED"
    print(f"  {doc}: {len(blocks)} block(s) {status}")
    failed |= proc.returncode != 0

sys.exit(1 if failed else 0)
EOF
echo "docs_check: all snippets pass"
