#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md).
#
#   scripts/tier1.sh           full suite (~4 min on CPU)
#   scripts/tier1.sh --smoke   fast subset (<60 s): skips @pytest.mark.slow
#
# Extra args after the optional --smoke are passed through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--smoke" ]]; then
  shift
  exec python -m pytest -x -q -m "not slow" "$@"
fi
exec python -m pytest -x -q "$@"
