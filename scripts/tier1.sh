#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md).
#
#   scripts/tier1.sh           full suite (~5 min on CPU): pytest, then
#                              docs snippets (scripts/docs_check.sh) and
#                              the examples at CI-friendly sizes
#   scripts/tier1.sh --smoke   fast subset (<60 s): skips @pytest.mark.slow
#                              and the docs/examples stages
#
# Extra args after the optional --smoke are passed through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--smoke" ]]; then
  shift
  exec python -m pytest -x -q -m "not slow" "$@"
fi

python -m pytest -x -q "$@"

echo "== docs snippets =="
scripts/docs_check.sh

echo "== examples (CI-sized) =="
python examples/quickstart.py --scale 9
python examples/graph_analytics.py --scale 9 --workers 4

echo "== CLI (registry-driven) =="
python -m repro list
python -m repro run wcc --scale 9

echo "== data-plane benchmark (smoke) + BENCH schema check =="
smoke_dir="$(mktemp -d)"
trap 'rm -rf "$smoke_dir"' EXIT
python -m benchmarks.channel_dataplane --scale 10 --repeats 2 \
  --out "$smoke_dir/BENCH_channel_dataplane.json"
# the smoke artifact and every committed BENCH_*.json share one schema
python -m benchmarks.check_schema "$smoke_dir/BENCH_channel_dataplane.json"

echo "== batched query plane (smoke) =="
python -m repro bench-batch --scale 10 --queries 4 --workers 4 \
  --keys pagerank:personal,sssp:prop
python -m benchmarks.query_throughput --scale 10 --queries 4 --repeats 1 \
  --keys pagerank:personal,sssp:prop \
  --out "$smoke_dir/BENCH_query_throughput.json"
python -m benchmarks.check_schema "$smoke_dir/BENCH_query_throughput.json"

echo "== routed-channel batching (smoke) =="
python -m repro bench-batch --scale 10 --queries 4 --workers 4 \
  --channel-class routed
python -m benchmarks.routed_batching --scale 10 --queries 4 --repeats 1 \
  --out "$smoke_dir/BENCH_routed_batching.json"
python -m benchmarks.check_schema "$smoke_dir/BENCH_routed_batching.json"

echo "== channel planner (smoke) =="
python -m repro plan --explain --scale 9 --workers 4
python -m benchmarks.planner --scale 10 --repeats 2 \
  --out "$smoke_dir/BENCH_planner.json"
python -m benchmarks.check_schema "$smoke_dir/BENCH_planner.json"

echo "== continuous-batching query service (smoke, <60s) =="
python -m repro serve --smoke
python -m benchmarks.serving --scale 8 --queries 6 --lanes 2 --chunk 2 \
  --keys reach:basic --out "$smoke_dir/BENCH_serving.json"
python -m benchmarks.check_schema "$smoke_dir/BENCH_serving.json"

echo "== resilience: fault injection + checkpoint/resume (smoke) =="
python -m repro run wcc:basic --scale 9 --chunk-size 2 \
  --checkpoint-every 2 --checkpoint-dir "$smoke_dir/ckpt"
python -m repro run wcc:basic --scale 9 --chunk-size 2 \
  --resume "$smoke_dir/ckpt"
python -m benchmarks.resilience --scale 9 \
  --out "$smoke_dir/BENCH_resilience.json"
python -m benchmarks.check_schema "$smoke_dir/BENCH_resilience.json"

echo "== weak scaling: degree-aware partitioning + hub mirroring (smoke) =="
# forced 1/2/4-device CPU meshes are spawned inside the benchmark's
# subprocesses (XLA flags must precede jax init); smoke checks the
# machinery + bit-identity, not the throughput target (tiny scales are
# overhead-dominated)
python -m benchmarks.weak_scaling --scale 10 --devices 1,2,4 --repeats 1 \
  --out "$smoke_dir/BENCH_weak_scaling.json" || true
python -m benchmarks.check_schema "$smoke_dir/BENCH_weak_scaling.json"
python - "$smoke_dir/BENCH_weak_scaling.json" <<'EOF'
import json, sys
h = json.load(open(sys.argv[1]))["headline"]
assert h["bit_identical"], "mirrored weak-scaling run not bit-identical"
print(f"weak-scaling smoke ok (bit_identical, ratio {h['per_device_ratio']})")
EOF
echo "tier1: all stages pass"
