"""Cost-model-driven channel planning (``Engine(plan="auto")``).

- :mod:`repro.plan.features` — graph/program fingerprints.
- :mod:`repro.plan.cost_model` — corpus-fitted cost curves + disk-cached
  calibration probes.
- :mod:`repro.plan.planner` — :class:`Plan` / :class:`Decision` /
  :class:`Planner`: abstract channel declarations lowered to the
  concrete knob assignment one compile runs under.
"""
from repro.plan.cost_model import Corpus, CostModel
from repro.plan.features import Fingerprint, fingerprint
from repro.plan.planner import Decision, Plan, Planner, manual_plan

__all__ = ["Corpus", "CostModel", "Fingerprint", "fingerprint",
           "Decision", "Plan", "Planner", "manual_plan"]
