"""Graph/program fingerprints — the planner's feature extraction.

A :class:`Fingerprint` is everything the cost model is allowed to see:
the execution substrate (backend / device kind / device count), the
partitioned graph's static shape surface (worker count, vertex counts,
edge count, degree statistics, the power-of-two slot caps that actually
enter compiled shapes), and the program's abstract declaration (its
data-plane family ``channel_class`` and the query-axis width). Two runs
with equal fingerprints are — by the same argument as
``repro.pregel.runtime.graph_signature`` — the same planning problem,
so the planner memoizes decisions and the calibration cache keys probe
timings by :func:`Fingerprint.cache_key`.

Degree statistics are rounded to one decimal: they feed *cost-curve
evaluation*, not compiled shapes, and coarse rounding keeps nearby
problem instances on one cache entry.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional, Tuple

import jax
import numpy as np

from repro.graph.pgraph import PartitionedGraph


@dataclasses.dataclass(frozen=True)
class Fingerprint:
    """The planner's view of one (device, graph, program, Q) problem."""

    backend: str          # jax.default_backend()
    device_kind: str      # e.g. "cpu", "TPU v4"
    device_count: int
    workers: int          # logical workers W
    n: int                # real vertices
    n_loc: int            # per-worker slot count
    edges: int            # real directed edges (sum of out-degrees)
    avg_degree: float     # edges / n, 1 decimal
    deg_skew: float       # max degree / avg degree, 1 decimal
    caps: Tuple[Tuple[str, int], ...]  # plan slot caps present (sorted)
    m_cap: int            # per-worker routed message bound (max raw e_cap)
    channel_class: str    # "static" | "routed" (ProgramSpec.channel_class)
    num_queries: int      # query-axis width (0 = unbatched)

    def cache_key(self) -> str:
        """Stable content hash — the calibration-cache file name."""
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, data: dict) -> "Fingerprint":
        data = dict(data)
        data["caps"] = tuple((str(k), int(v)) for k, v in data["caps"])
        return cls(**data)


def channel_class_of(prog) -> str:
    """The program's abstract data-plane family: the registry's
    ``channel_class`` when the program is registered (programs name
    themselves ``algorithm:variant``, the registry key), else the
    program's own ``meta`` hint, else ``"static"``."""
    meta = getattr(prog, "meta", None) or {}
    if "channel_class" in meta:
        return meta["channel_class"]
    # lazy: algorithms imports the engine, which imports this module
    from repro.algorithms import channel_class_of as registry_class

    return registry_class(getattr(prog, "name", ""))


def _plan_caps(pg: PartitionedGraph) -> Tuple[Tuple[str, int], ...]:
    caps = {}
    for field in ("scatter_out", "scatter_in"):
        plan = getattr(pg, field)
        if plan is not None:
            caps[f"{field}.e_cap"] = plan.e_cap
            caps[f"{field}.u_cap"] = plan.u_cap
            caps[f"{field}.slot_cap"] = plan.slot_cap
    for field in ("prop_out", "prop_in"):
        plan = getattr(pg, field)
        if plan is not None:
            caps[f"{field}.ei_cap"] = plan.ei_cap
            caps[f"{field}.cut.e_cap"] = plan.cut.e_cap
            caps[f"{field}.cut.slot_cap"] = plan.cut.slot_cap
    for field in ("raw_out", "raw_in"):
        plan = getattr(pg, field)
        if plan is not None:
            caps[f"{field}.e_cap"] = plan.e_cap
    return tuple(sorted(caps.items()))


def fingerprint(prog, pg: PartitionedGraph,
                num_queries: int = 0,
                backend: Optional[str] = None) -> Fingerprint:
    """Extract the planning fingerprint of running ``prog`` on ``pg``.

    Cheap (two device reductions over ``deg_out``) and side-effect free:
    no compile-cache entries, no stats counters — the extraction itself
    never touches the Engine.
    """
    deg = np.asarray(pg.deg_out)
    mask = np.asarray(pg.v_mask)
    edges = int(deg.sum())
    n = int(mask.sum())
    avg = edges / max(n, 1)
    max_deg = int(deg.max(initial=0))
    caps = _plan_caps(pg)
    raw_caps = [v for k, v in caps if k.startswith("raw_") and
                k.endswith("e_cap")]
    dev = jax.devices()[0]
    return Fingerprint(
        backend=backend or jax.default_backend(),
        device_kind=str(getattr(dev, "device_kind", dev.platform)),
        device_count=jax.device_count(),
        workers=pg.num_workers,
        n=n,
        n_loc=pg.n_loc,
        edges=edges,
        avg_degree=round(avg, 1),
        deg_skew=round(max_deg / max(avg, 1e-9), 1),
        caps=caps,
        m_cap=max(raw_caps, default=pg.n_loc),
        channel_class=channel_class_of(prog),
        num_queries=int(num_queries),
    )
