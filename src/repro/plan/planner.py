"""The planner: abstract channel declarations -> one concrete ``Plan``.

A program declares *what* its channels do (the registry's
``channel_class``, the graph plans it needs); the planner decides *how*
each declaration is lowered, producing a :class:`Plan` — the full knob
assignment ``(mode, chunk_size, use_kernel, route_impl, route_batch,
dense_threshold)`` plus one :class:`Decision` record per knob with the
candidate costs that justified it. ``Engine(plan="auto")`` resolves a
Plan per (program, graph shape, Q), folds it into the compile-cache key,
and stamps it on ``RunResult.plan``; ``python -m repro plan --explain``
prints the decision table.

Guarantees:

- **Determinism**: equal fingerprints -> equal plans, across processes,
  calibration cache warm or cold. Probe-informed decisions only pick
  between candidates whose measured margins are large (bucket-vs-sort
  ~2x, kernel-vs-reference ~20x on CPU); the density threshold is fitted
  purely from the committed corpus.
- **Explicit wins**: any knob the caller set (an ``Engine(...)``
  argument, a CLI flag) is taken verbatim and recorded with source
  ``"explicit"`` — the planner never overrides a human.
- **Bit-identity**: a Plan only selects among implementations that are
  already proven output-identical (the routed exchange contracts, the
  kernel-vs-reference parity tests), so a planned run's output equals
  the hand-set run with the same knobs, bit for bit.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple

import jax

from repro.plan import cost_model as cm
from repro.plan import features

KNOBS = ("mode", "chunk_size", "use_kernel", "route_impl", "route_batch",
         "dense_threshold")


@dataclasses.dataclass(frozen=True)
class Decision:
    """One planned knob: what was chosen, on what evidence.

    candidates: ``(name, predicted_s, measured_s)`` tuples (costs may be
    None when a source had no evidence for that candidate).
    """

    knob: str
    chosen: Any
    source: str = "planner"   # "planner" | "explicit" | "default"
    candidates: Tuple[Tuple[str, Optional[float], Optional[float]], ...] = ()
    reason: str = ""

    def to_json(self) -> dict:
        return {"knob": self.knob, "chosen": self.chosen,
                "source": self.source,
                "candidates": [list(c) for c in self.candidates],
                "reason": self.reason}

    @classmethod
    def from_json(cls, data: dict) -> "Decision":
        return cls(knob=data["knob"], chosen=data["chosen"],
                   source=data["source"],
                   candidates=tuple(
                       (c[0], c[1], c[2]) for c in data["candidates"]),
                   reason=data.get("reason", ""))


@dataclasses.dataclass(frozen=True)
class Plan:
    """A concrete lowering of every declared channel: the full knob
    assignment one Engine compile runs under. Hashable and static — it
    enters the Engine compile-cache key via :meth:`key` and is stamped
    on ``RunResult.plan``."""

    mode: str = "fused"
    chunk_size: int = 64
    use_kernel: bool = False
    route_impl: str = "bucket"
    route_batch: str = "union"
    dense_threshold: float = 0.1
    source: str = "manual"    # "manual" | "auto" | "given"
    fingerprint: Optional[features.Fingerprint] = None
    decisions: Tuple[Decision, ...] = ()

    def key(self) -> Tuple:
        """The hashable knob tuple a compile is cached under."""
        return (self.mode, self.chunk_size, self.use_kernel,
                self.route_impl, self.route_batch, self.dense_threshold)

    def knobs(self) -> Dict[str, Any]:
        return {k: getattr(self, k) for k in KNOBS}

    def decision(self, knob: str) -> Optional[Decision]:
        for d in self.decisions:
            if d.knob == knob:
                return d
        return None

    # -- serialization (RunResult.plan must round-trip through JSON) ------

    def to_json(self) -> dict:
        return {
            **self.knobs(),
            "source": self.source,
            "fingerprint": (None if self.fingerprint is None
                            else self.fingerprint.to_json()),
            "decisions": [d.to_json() for d in self.decisions],
        }

    @classmethod
    def from_json(cls, data) -> "Plan":
        if isinstance(data, str):
            data = json.loads(data)
        return cls(
            mode=data["mode"], chunk_size=int(data["chunk_size"]),
            use_kernel=bool(data["use_kernel"]),
            route_impl=data["route_impl"], route_batch=data["route_batch"],
            dense_threshold=float(data["dense_threshold"]),
            source=data.get("source", "given"),
            fingerprint=(None if data.get("fingerprint") is None
                         else features.Fingerprint.from_json(
                             data["fingerprint"])),
            decisions=tuple(Decision.from_json(d)
                            for d in data.get("decisions", ())),
        )

    # -- presentation ------------------------------------------------------

    def explain(self) -> str:
        """The decision table ``repro plan --explain`` prints: one row
        per knob with the chosen value, its source, and the predicted vs
        measured cost of every candidate."""
        fmt = lambda v: "-" if v is None else f"{v * 1e3:9.3f}ms"
        lines = [f"plan [{self.source}]"
                 + (f"  fingerprint {self.fingerprint.cache_key()}"
                    if self.fingerprint else "")]
        header = (f"  {'knob':16s} {'chosen':10s} {'source':9s} "
                  f"{'candidate':10s} {'predicted':>11s} {'measured':>11s}")
        lines += [header, "  " + "-" * (len(header) - 2)]
        for knob in KNOBS:
            dec = self.decision(knob)
            chosen = getattr(self, knob)
            if dec is None or not dec.candidates:
                lines.append(f"  {knob:16s} {str(chosen):10s} "
                             f"{(dec.source if dec else 'manual'):9s}")
                if dec and dec.reason:
                    lines.append(f"    ^ {dec.reason}")
                continue
            chosen_name = str(chosen)
            if knob == "use_kernel":
                chosen_name = "kernel" if chosen else "reference"
            first = True
            for name, pred, meas in dec.candidates:
                head = (f"  {knob:16s} {str(chosen):10s} {dec.source:9s}"
                        if first else f"  {'':16s} {'':10s} {'':9s}")
                mark = "*" if name == chosen_name else " "
                lines.append(f"{head} {mark}{name:9s} {fmt(pred):>11s} "
                             f"{fmt(meas):>11s}")
                first = False
            if dec.reason:
                lines.append(f"    ^ {dec.reason}")
        return "\n".join(lines)


# Plans are all-static: register so a Plan may ride through jit-adjacent
# plumbing (pytree flatten treats it as a leafless constant).
try:
    jax.tree_util.register_static(Plan)
    jax.tree_util.register_static(Decision)
    jax.tree_util.register_static(features.Fingerprint)
except (AttributeError, ValueError):  # older jax or double-registration
    pass


def manual_plan(*, mode: str = "fused", chunk_size: int = 64,
                use_kernel: Optional[bool] = None,
                route_impl: Optional[str] = None,
                route_batch: Optional[str] = None,
                dense_threshold: Optional[float] = None,
                explicit: Dict[str, Any] = None) -> Plan:
    """The hand-set path as a Plan: resolve every knob through its own
    config ladder (explicit > scope > env > default) and record where
    each value came from — what ``Engine(plan="manual")`` stamps."""
    from repro.core import compose, routing
    from repro.kernels import ops as kops

    explicit = explicit or {}
    values = {
        "mode": mode,
        "chunk_size": chunk_size,
        "use_kernel": kops.resolve_use_kernel(use_kernel),
        "route_impl": routing.resolve_impl(route_impl),
        "route_batch": routing.resolve_batch(route_batch),
        "dense_threshold": compose.resolve_dense_threshold(dense_threshold),
    }
    decisions = tuple(
        Decision(knob=k, chosen=values[k],
                 source="explicit" if explicit.get(k) is not None
                 else "default",
                 reason="" if explicit.get(k) is not None
                 else "config ladder (scope > env > default)")
        for k in KNOBS)
    return Plan(source="manual", decisions=decisions, **values)


class Planner:
    """Fingerprint -> Plan, memoized. One planner per Engine."""

    def __init__(self, calibrate: bool = True,
                 corpus: Optional[cm.Corpus] = None):
        self.calibrate = calibrate
        self._corpus = corpus
        self._memo: Dict[Tuple, Plan] = {}

    @property
    def corpus(self) -> cm.Corpus:
        if self._corpus is None:
            self._corpus = cm.Corpus.load()
        return self._corpus

    def plan(self, prog, pg, num_queries: int = 0,
             overrides: Optional[Dict[str, Any]] = None) -> Plan:
        """Lower ``prog``-on-``pg`` (Q query lanes) to a concrete Plan.

        overrides: explicitly-set knob values (None entries ignored) —
        taken verbatim, recorded with source "explicit".
        """
        overrides = {k: v for k, v in (overrides or {}).items()
                     if v is not None}
        fp = features.fingerprint(prog, pg, num_queries=num_queries)
        memo_key = (fp, tuple(sorted(overrides.items())))
        hit = self._memo.get(memo_key)
        if hit is not None:
            return hit
        plan = self._decide(fp, overrides)
        self._memo[memo_key] = plan
        return plan

    # -- the decision procedure -------------------------------------------

    def _decide(self, fp: features.Fingerprint,
                overrides: Dict[str, Any]) -> Plan:
        model = cm.CostModel.build(fp, calibrate_probes=self.calibrate,
                                   corpus=self.corpus)
        values: Dict[str, Any] = {}
        decisions = []

        def decide(knob, chosen, candidates=(), reason=""):
            if knob in overrides:
                decisions.append(Decision(
                    knob=knob, chosen=overrides[knob], source="explicit",
                    candidates=tuple(candidates),
                    reason="caller-set knob — planner does not override"))
                values[knob] = overrides[knob]
            else:
                decisions.append(Decision(
                    knob=knob, chosen=chosen, source="planner",
                    candidates=tuple(candidates), reason=reason))
                values[knob] = chosen

        def pick(costs, names):
            """argmin by measured cost when both candidates were probed,
            else by predicted cost; returns (winner, cands, basis)."""
            cands = tuple(
                (n, costs[n]["predicted"], costs[n]["measured"])
                for n in names)
            by_meas = {n: costs[n]["measured"] for n in names}
            by_pred = {n: costs[n]["predicted"] for n in names}
            if all(v is not None for v in by_meas.values()):
                basis, table = "measured probe", by_meas
            elif all(v is not None for v in by_pred.values()):
                basis, table = "corpus fit", by_pred
            else:
                return None, cands, None
            return min(table, key=table.get), cands, basis

        # mode / chunk_size: the fused while_loop amortizes per-superstep
        # dispatch (BENCH_superstep_fusion) — always the planned default;
        # chunked/host remain caller choices (serving, step inspection).
        decide("mode", "fused", reason=(
            "fused while_loop amortizes per-superstep dispatch overhead "
            "(BENCH_superstep_fusion)"))
        decide("chunk_size", 64, reason=(
            "inert under mode='fused'; 64 balances dispatch amortization "
            "vs halt-check latency for chunked/serve substrates"))

        # use_kernel: combine-probe argmin (ref on CPU where the Pallas
        # kernel runs interpreted; the kernel on TPU where it lowers)
        winner, cands, basis = pick(model.combine_costs(),
                                    ("reference", "kernel"))
        if winner is None:
            from repro.kernels import ops as kops

            decide("use_kernel", kops.resolve_use_kernel(None),
                   candidates=cands,
                   reason="no cost evidence — backend default")
        else:
            decide("use_kernel", winner == "kernel", candidates=cands,
                   reason=f"cheaper segment combine at e_cap ({basis})")

        # route_impl: route-probe argmin (bucket's one-pass counting sort
        # beats the argsort baseline ~2x at this library's worker counts)
        winner, cands, basis = pick(model.route_costs(), ("bucket", "sort"))
        if winner is None:
            decide("route_impl", "bucket", candidates=cands,
                   reason="no cost evidence — library default")
        else:
            decide("route_impl", winner, candidates=cands,
                   reason=f"cheaper routed exchange at m_cap ({basis})")

        # route_batch: only live for Q>1 routed programs; the corpus
        # union-vs-lane geomean is the prior
        prior = model.union_prior()
        if fp.num_queries > 1 and fp.channel_class == "routed":
            chosen = "union" if (prior or 1.0) >= 1.0 else "lane"
            decide("route_batch", chosen, candidates=(
                ("union", None, None), ("lane", None, None)),
                reason=(f"corpus union-vs-lane geomean "
                        f"{prior:.2f}x across routed programs"
                        if prior else "library default (no corpus)"))
        else:
            decide("route_batch", "union", reason=(
                "inert: no routed channels under a query batch "
                f"(Q={fp.num_queries}, class={fp.channel_class!r})"))

        # dense_threshold: the corpus-fitted switch crossing
        thr, reason = model.dense_threshold()
        decide("dense_threshold", thr, reason=reason)

        return Plan(source="auto", fingerprint=fp,
                    decisions=tuple(decisions), **values)
