"""The planner's cost model: corpus-fitted curves + calibration probes.

Two evidence sources, in order of authority:

1. **The committed benchmark corpus** (``BENCH_channel_dataplane.json``,
   ``BENCH_routed_batching.json``, ``BENCH_query_throughput.json``,
   ``BENCH_serving.json``): per-decision cost curves measured by this
   repo's own benchmarks. The dataplane artifact gives log-log power-law
   fits of route cost (sort vs bucket, per wire-message count) and
   combine cost (jnp reference vs Pallas kernel, per edge count); the
   routed-batching artifact gives the union-vs-lane speedup prior. Fits
   from committed JSON are **deterministic** — they anchor every decision
   whose margin must survive process restarts.

2. **Calibration probes**: cheap one-shot micro-exchanges timed at the
   fingerprint's own cap bucket on the *local* device (the corpus may
   have been recorded on different hardware — its provenance block says
   which). Probe timings are cached on disk under ``.repro_plan_cache/``
   (override with ``REPRO_PLAN_CACHE``), keyed by
   :func:`repro.plan.features.Fingerprint.cache_key`, so a session pays
   each fingerprint's probes once ever. Probes use their own jitted
   closures — they never enter an Engine compile cache and never touch
   ``Engine.stats()`` counters.

A decision consumes ``predicted`` (corpus fit) and ``measured`` (probe)
costs per candidate; the planner picks by measured cost when probes ran,
else by prediction, and ``repro plan --explain`` prints both columns.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Callable, Dict, Optional

import numpy as np

from repro.plan.features import Fingerprint

CORPUS_FILES = (
    "BENCH_channel_dataplane.json",
    "BENCH_routed_batching.json",
    "BENCH_query_throughput.json",
    "BENCH_serving.json",
)

#: coarse grid the density-switch threshold is quantized to — coarse on
#: purpose: the crossing estimate is a model output, and snapping it to a
#: sparse grid keeps plans bit-stable under small corpus refreshes
THRESHOLD_GRID = (0.02, 0.05, 0.1, 0.15, 0.2, 0.3, 0.5)

PROBE_REPEATS = 3
PROBE_M_MAX = 16384   # route-probe message bound
PROBE_E_MAX = 4096    # combine-probe edge bound


def corpus_dir(start: Optional[pathlib.Path] = None) -> Optional[pathlib.Path]:
    """Locate the committed BENCH corpus: ``REPRO_BENCH_CORPUS``, then
    the working directory and its parents, then this checkout's root."""
    env = os.environ.get("REPRO_BENCH_CORPUS")
    candidates = []
    if env:
        candidates.append(pathlib.Path(env))
    cwd = pathlib.Path(start or ".").resolve()
    candidates.extend([cwd, *cwd.parents])
    candidates.append(pathlib.Path(__file__).resolve().parents[3])
    for cand in candidates:
        if (cand / CORPUS_FILES[0]).is_file():
            return cand
    return None


@dataclasses.dataclass
class PowerFit:
    """A log-log linear fit ``t(x) = exp(b) * x**a`` of (x, seconds)."""

    a: float
    b: float

    @classmethod
    def fit(cls, xs, ts) -> Optional["PowerFit"]:
        xs = np.asarray(xs, float)
        ts = np.asarray(ts, float)
        ok = (xs > 0) & (ts > 0)
        if ok.sum() < 2:
            return None
        a, b = np.polyfit(np.log(xs[ok]), np.log(ts[ok]), 1)
        return cls(a=float(a), b=float(b))

    def predict(self, x: float) -> float:
        return float(np.exp(self.b) * max(x, 1.0) ** self.a)


@dataclasses.dataclass
class Corpus:
    """The fitted curves extracted from the committed artifacts."""

    route_sort: Optional[PowerFit] = None     # seconds vs m_per_worker
    route_bucket: Optional[PowerFit] = None
    combine_ref: Optional[PowerFit] = None    # seconds vs edges
    combine_kernel: Optional[PowerFit] = None
    combine_kernel_interpret: bool = True     # corpus kernel column mode
    union_vs_lane: Optional[float] = None     # geomean speedup prior
    source_dir: Optional[str] = None

    @classmethod
    def load(cls, root: Optional[pathlib.Path] = None) -> "Corpus":
        root = root or corpus_dir()
        if root is None:
            return cls()
        out = cls(source_dir=str(root))
        try:
            data = json.loads(
                (root / "BENCH_channel_dataplane.json").read_text())
            route = list(data.get("route", {}).values())
            out.route_sort = PowerFit.fit(
                [r["m_per_worker"] for r in route],
                [r["sort_s"] for r in route])
            out.route_bucket = PowerFit.fit(
                [r["m_per_worker"] for r in route],
                [r["bucket_s"] for r in route])
            comb = list(data.get("combine", {}).values())
            out.combine_ref = PowerFit.fit(
                [r["edges"] for r in comb], [r["ref_s"] for r in comb])
            out.combine_kernel = PowerFit.fit(
                [r["edges"] for r in comb], [r["kernel_s"] for r in comb])
            out.combine_kernel_interpret = bool(
                comb[0].get("kernel_interpret", True)) if comb else True
        except (OSError, ValueError, KeyError):
            pass
        try:
            data = json.loads(
                (root / "BENCH_routed_batching.json").read_text())
            ratios = [p["union_vs_lane"]
                      for p in data.get("programs", {}).values()
                      if p.get("union_vs_lane", 0) > 0]
            if ratios:
                out.union_vs_lane = float(np.exp(np.mean(np.log(ratios))))
        except (OSError, ValueError, KeyError):
            pass
        return out


# ---------------------------------------------------------------------------
# calibration probes (device-local, disk-cached, engine-invisible)
# ---------------------------------------------------------------------------


def cache_dir() -> pathlib.Path:
    return pathlib.Path(os.environ.get("REPRO_PLAN_CACHE",
                                       ".repro_plan_cache"))


def _timed(fn: Callable[[], object]) -> float:
    """min-of-N wall time of a blocking thunk (first call excluded — it
    pays the probe's own jit)."""
    import jax

    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(PROBE_REPEATS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _run_probes(fp: Fingerprint) -> Dict[str, float]:
    """Time the micro-exchanges behind each decision at ``fp``'s scale.

    Inputs are deterministic in the fingerprint (seeded generator), so a
    probe re-run measures the same computation.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import routing
    from repro.kernels import ops as kops

    w = max(fp.workers, 2)
    m = int(min(max(fp.m_cap, 256), PROBE_M_MAX))
    e = int(min(max(fp.m_cap, 256), PROBE_E_MAX))
    segs = max(min(fp.n_loc, e // 2), 8)
    rng = np.random.default_rng(12345)
    keys = jnp.asarray(rng.integers(0, w, size=m), jnp.int32)
    vals = jnp.asarray(rng.random(e), jnp.float32)
    seg_ids = jnp.asarray(np.sort(rng.integers(0, segs, size=e)), jnp.int32)

    bucket = jax.jit(lambda k: kops.bucket_ranks(k, w, use_kernel=False))
    sort = jax.jit(lambda k: routing._slots_sort(k, w))
    ref = jax.jit(lambda v, s: kops.segment_combine(
        v, s, segs, "min", use_kernel=False, assume_sorted=True))
    kern = jax.jit(lambda v, s: kops.segment_combine(
        v, s, segs, "min", use_kernel=True, assume_sorted=True))

    probes = {
        "m_probe": float(m),
        "e_probe": float(e),
        "route_bucket_s": _timed(lambda: bucket(keys)),
        "route_sort_s": _timed(lambda: sort(keys)),
        "combine_ref_s": _timed(lambda: ref(vals, seg_ids)),
        "combine_kernel_s": _timed(lambda: kern(vals, seg_ids)),
    }
    return probes


def calibrate(fp: Fingerprint, enable: bool = True) -> Dict[str, float]:
    """Probe timings for ``fp`` — from the on-disk cache when warm, else
    measured once and written back. ``enable=False`` skips probing
    entirely (corpus-only planning) and returns ``{}``."""
    if not enable:
        return {}
    path = cache_dir() / f"{fp.cache_key()}.json"
    try:
        cached = json.loads(path.read_text())
        # normalize through from_json: the disk round-trip turns the caps
        # tuple into lists, so a raw dict comparison would never match
        if Fingerprint.from_json(cached["fingerprint"]) == fp:
            return cached["probes"]
    except (OSError, ValueError, KeyError, TypeError):
        pass
    probes = _run_probes(fp)
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            {"fingerprint": fp.to_json(), "probes": probes}, indent=1))
        tmp.replace(path)
    except OSError:  # read-only checkout: plan uncached, never fail
        pass
    return probes


# ---------------------------------------------------------------------------
# the model: per-decision candidate costs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CostModel:
    """Candidate costs for each planner decision at one fingerprint."""

    fp: Fingerprint
    corpus: Corpus
    probes: Dict[str, float]

    @classmethod
    def build(cls, fp: Fingerprint, calibrate_probes: bool = True,
              corpus: Optional[Corpus] = None) -> "CostModel":
        return cls(fp=fp, corpus=corpus or Corpus.load(),
                   probes=calibrate(fp, enable=calibrate_probes))

    # -- per-decision (predicted, measured) cost pairs ---------------------

    def route_costs(self) -> Dict[str, Dict[str, Optional[float]]]:
        """Seconds per routed exchange at the fingerprint's cap, for each
        route_impl candidate."""
        m = self.fp.m_cap
        return {
            "bucket": {
                "predicted": (self.corpus.route_bucket.predict(m)
                              if self.corpus.route_bucket else None),
                "measured": self.probes.get("route_bucket_s"),
            },
            "sort": {
                "predicted": (self.corpus.route_sort.predict(m)
                              if self.corpus.route_sort else None),
                "measured": self.probes.get("route_sort_s"),
            },
        }

    def combine_costs(self) -> Dict[str, Dict[str, Optional[float]]]:
        """Seconds per segment combine at the fingerprint's edge cap, for
        each use_kernel candidate. Corpus kernel predictions only apply
        when the local device matches the corpus's kernel mode (an
        interpret-mode CPU curve says nothing about a real TPU lowering
        — there the probe is the only evidence)."""
        e = max(v for k, v in self.fp.caps if k.endswith("e_cap")) \
            if self.fp.caps else self.fp.m_cap
        interpret_here = self.fp.backend != "tpu"
        kernel_pred = None
        if (self.corpus.combine_kernel is not None
                and self.corpus.combine_kernel_interpret == interpret_here):
            kernel_pred = self.corpus.combine_kernel.predict(e)
        return {
            "reference": {
                "predicted": (self.corpus.combine_ref.predict(e)
                              if self.corpus.combine_ref else None),
                "measured": self.probes.get("combine_ref_s"),
            },
            "kernel": {
                "predicted": kernel_pred,
                "measured": self.probes.get("combine_kernel_s"),
            },
        }

    def union_prior(self) -> Optional[float]:
        """Corpus geomean of union-vs-lane batched-routing speedup."""
        return self.corpus.union_vs_lane

    def dense_threshold(self) -> tuple:
        """The density-switch crossing: the frontier fraction where the
        routed sparse push (route + combine over ``f*m`` live messages)
        stops undercutting the planned dense broadcast (combine over all
        ``m`` edges, frontier-independent).

        Corpus-fit only — committed JSON in, deterministic threshold out
        (probe noise must never move a plan between processes). Returns
        ``(threshold, reason)``; no corpus -> the knob default 0.1.
        """
        route = self.corpus.route_bucket or self.corpus.route_sort
        combine = self.corpus.combine_ref
        m = float(self.fp.m_cap)
        if route is None or combine is None:
            return 0.1, "no corpus curves — knob default"
        dense_cost = combine.predict(m)
        fracs = np.linspace(0.01, 1.0, 200)
        sparse = np.array([route.predict(f * m) + combine.predict(f * m)
                           for f in fracs])
        cheaper = fracs[sparse < dense_cost]
        crossing = float(cheaper.max()) if len(cheaper) else 0.01
        grid = np.asarray(THRESHOLD_GRID)
        thr = float(grid[np.argmin(np.abs(grid - crossing))])
        return thr, (f"sparse push undercuts dense broadcast below "
                     f"frontier fraction ~{crossing:.2f} at m={int(m)} "
                     f"(corpus fit), snapped to grid")
