"""Weakly Connected Components — HCC min-label (paper Table V bottom).

Variants:
  - "basic":  per-superstep CombinedMessage: changed vertices send their
              label to all neighbors (Pregel/HCC style, O(diameter) steps).
  - "prop":   the Propagation channel (local fixpoint between exchanges).
  - "switch": the density-adaptive data plane (paper §V,
              ``repro.core.compose.density_adaptive_combine``): each
              superstep the live frontier fraction (from the loop carry)
              picks the *planned* ScatterCombine broadcast (dense —
              static positional plan, no ids on the wire) at or above
              ``dense_threshold``, and the *routed* CombinedMessage push
              (sparse — bucket-routed, only changed labels travel)
              below it. Labels, supersteps
              and halting are bit-identical to "basic" (min-label is
              idempotent; re-broadcasting an unchanged label never
              changes the minimum) — only the traffic profile moves,
              attributed under ``wcc/dense/...`` / ``wcc/sparse/...``.

The graph must be symmetrized (undirected view). "switch" needs both the
``scatter_out`` and ``raw_out`` plans.

``program(variant=...)`` builds the declarative
:class:`~repro.pregel.program.VertexProgram`; ``run`` is the thin
one-shot wrapper over :class:`repro.pregel.engine.Engine`.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import compose
from repro.core import message as msg
from repro.core import propagation as prop
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import engine
from repro.pregel.program import VertexProgram

INF32 = jnp.iinfo(jnp.int32).max

VARIANTS = ("basic", "prop", "switch")


def program(variant: str = "prop", *, max_steps: int = 10_000,
            dense_threshold: Optional[float] = None) -> VertexProgram:
    """Min-label WCC as a VertexProgram. Output: (n,) component labels in
    old-id space (min member id per component, canonicalized by tests)."""
    if variant not in VARIANTS:
        raise ValueError(variant)

    def extract(pg, state):
        return pg.to_global(state["lab"])

    if variant == "prop":

        def init(pg):
            ids = pg.global_ids().astype(jnp.int32)
            return {
                "lab": jnp.where(pg.v_mask, ids, INF32),
                "info": jnp.zeros((pg.num_workers, 2), jnp.int32),
            }

        def step(ctx, gs, state, step_idx):
            lab0 = state["lab"]
            lab, rounds, iters = prop.propagate(ctx, gs.prop_out, lab0, "min")
            lab = jnp.where(gs.v_mask, lab, INF32)
            info = jnp.stack([rounds, iters]).astype(jnp.int32)
            return {"lab": lab, "info": info}, True

        return VertexProgram(
            name="wcc:prop", init=init, step=step, extract=extract,
            max_steps=1, meta={"algorithm": "wcc", "variant": variant},
        )

    # "basic" and "switch" share the min-label step; they differ only in
    # the exchange that delivers neighbor labels
    def exchange(ctx, gs, lab, active):
        raw = gs.raw_out
        valid = raw.mask & active[raw.src_local]

        if variant == "basic":
            inc, _, ovf = msg.combined_send(
                ctx, raw.dst_global, valid, lab[raw.src_local], "min",
                capacity=ctx.edge_capacity(ctx.n_loc),
            )
            return inc, ovf

        # density-adaptive data plane: the live frontier fraction (from
        # the carry) picks the planned broadcast (dense) or the routed
        # compact push (sparse) each superstep
        frac = compose.global_fraction(
            ctx, jnp.sum(active & gs.v_mask), jnp.sum(gs.v_mask)
        )
        inc, ovf, _ = compose.density_adaptive_combine(
            ctx, "wcc", frac, dense_threshold,
            plan=gs.scatter_out,
            dense_vals=jnp.where(gs.v_mask, lab, INF32),
            dst=raw.dst_global, valid=valid,
            sparse_vals=lab[raw.src_local],
            combiner="min", capacity=ctx.edge_capacity(ctx.n_loc),
        )
        return inc, ovf

    def init(pg):
        ids = pg.global_ids().astype(jnp.int32)
        return {
            "lab": jnp.where(pg.v_mask, ids, INF32),
            "active": pg.v_mask,
        }

    def step(ctx, gs, state, step_idx):
        lab, active = state["lab"], state["active"]
        inc, overflow = exchange(ctx, gs, lab, active)
        new = jnp.where(gs.v_mask, jnp.minimum(lab, inc), lab)
        new_active = new != lab
        halt = ~jnp.any(new_active)
        return {"lab": new, "active": new_active}, halt, overflow

    return VertexProgram(
        name=f"wcc:{variant}", init=init, step=step, extract=extract,
        max_steps=max_steps,
        meta={"algorithm": "wcc", "variant": variant,
              "dense_threshold": dense_threshold},
    )


def run(pg: PartitionedGraph, variant: str = "prop", max_steps: int = 10_000,
        backend: str = "vmap", mesh=None, mode=None, chunk_size: int = 64,
        dense_threshold: Optional[float] = None, route_impl=None):
    prog = program(variant=variant, max_steps=max_steps,
                   dense_threshold=dense_threshold)
    res = engine.run_program(prog, pg, backend=backend, mesh=mesh, mode=mode,
                             chunk_size=chunk_size, route_impl=route_impl)
    return res.output, res
