"""Weakly Connected Components — HCC min-label (paper Table V bottom).

Variants:
  - "basic": per-superstep CombinedMessage: changed vertices send their
             label to all neighbors (Pregel/HCC style, O(diameter) steps).
  - "prop":  the Propagation channel (local fixpoint between exchanges).

The graph must be symmetrized (undirected view).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import message as msg
from repro.core import propagation as prop
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import runtime

INF32 = jnp.iinfo(jnp.int32).max


def run(pg: PartitionedGraph, variant: str = "prop", max_steps: int = 10_000,
        backend: str = "vmap", mesh=None, mode=None, chunk_size: int = 64):
    ids = pg.global_ids().astype(jnp.int32)

    if variant == "prop":

        def step(ctx, gs, state, step_idx):
            lab0 = state["lab"]
            lab, rounds, iters = prop.propagate(ctx, gs.prop_out, lab0, "min")
            lab = jnp.where(gs.v_mask, lab, INF32)
            info = jnp.stack([rounds, iters]).astype(jnp.int32)
            return {"lab": lab, "info": info}, True

        state0 = {
            "lab": jnp.where(pg.v_mask, ids, INF32),
            "info": jnp.zeros((pg.num_workers, 2), jnp.int32),
        }
        res = runtime.run_supersteps(pg, step, state0, max_steps=1,
                                     backend=backend, mesh=mesh, mode=mode,
                                     chunk_size=chunk_size)
    elif variant == "basic":

        def step(ctx, gs, state, step_idx):
            lab, active = state["lab"], state["active"]
            raw = gs.raw_out
            send_val = lab[raw.src_local]
            valid = raw.mask & active[raw.src_local]
            inc, got, overflow = msg.combined_send(
                ctx, raw.dst_global, valid, send_val, "min", capacity=ctx.n_loc
            )
            new = jnp.where(gs.v_mask, jnp.minimum(lab, inc), lab)
            new_active = new != lab
            halt = ~jnp.any(new_active)
            return {"lab": new, "active": new_active}, halt, overflow

        state0 = {
            "lab": jnp.where(pg.v_mask, ids, INF32),
            "active": pg.v_mask,
        }
        res = runtime.run_supersteps(pg, step, state0, max_steps=max_steps,
                                     backend=backend, mesh=mesh, mode=mode,
                                     chunk_size=chunk_size)
    else:
        raise ValueError(variant)

    return pg.to_global(res.state["lab"]), res
