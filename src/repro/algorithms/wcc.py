"""Weakly Connected Components — HCC min-label (paper Table V bottom).

Variants:
  - "basic":  per-superstep CombinedMessage: changed vertices send their
              label to all neighbors (Pregel/HCC style, O(diameter) steps).
  - "prop":   the Propagation channel (local fixpoint between exchanges).
  - "switch": the composition layer's density switch (paper §V,
              ``repro.core.compose.switch_by_density``): each superstep
              picks the ScatterCombine broadcast (dense — static plan, no
              ids on the wire) when the active fraction is at or above
              ``dense_threshold``, and the CombinedMessage push (sparse —
              only changed labels travel) below it. Labels, supersteps
              and halting are bit-identical to "basic" (min-label is
              idempotent; re-broadcasting an unchanged label never
              changes the minimum) — only the traffic profile moves,
              attributed under ``wcc/dense/...`` / ``wcc/sparse/...``.

The graph must be symmetrized (undirected view). "switch" needs both the
``scatter_out`` and ``raw_out`` plans.

``program(variant=...)`` builds the declarative
:class:`~repro.pregel.program.VertexProgram`; ``run`` is the thin
one-shot wrapper over :class:`repro.pregel.engine.Engine`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import compose
from repro.core import message as msg
from repro.core import propagation as prop
from repro.core import scatter_combine as sc
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import engine
from repro.pregel.program import VertexProgram

INF32 = jnp.iinfo(jnp.int32).max

VARIANTS = ("basic", "prop", "switch")


def program(variant: str = "prop", *, max_steps: int = 10_000,
            dense_threshold: float = 0.1) -> VertexProgram:
    """Min-label WCC as a VertexProgram. Output: (n,) component labels in
    old-id space (min member id per component, canonicalized by tests)."""
    if variant not in VARIANTS:
        raise ValueError(variant)

    def extract(pg, state):
        return pg.to_global(state["lab"])

    if variant == "prop":

        def init(pg):
            ids = pg.global_ids().astype(jnp.int32)
            return {
                "lab": jnp.where(pg.v_mask, ids, INF32),
                "info": jnp.zeros((pg.num_workers, 2), jnp.int32),
            }

        def step(ctx, gs, state, step_idx):
            lab0 = state["lab"]
            lab, rounds, iters = prop.propagate(ctx, gs.prop_out, lab0, "min")
            lab = jnp.where(gs.v_mask, lab, INF32)
            info = jnp.stack([rounds, iters]).astype(jnp.int32)
            return {"lab": lab, "info": info}, True

        return VertexProgram(
            name="wcc:prop", init=init, step=step, extract=extract,
            max_steps=1, meta={"algorithm": "wcc", "variant": variant},
        )

    # "basic" and "switch" share the min-label step; they differ only in
    # the exchange that delivers neighbor labels
    def exchange(ctx, gs, lab, active):
        raw = gs.raw_out

        def sparse(sub):
            valid = raw.mask & active[raw.src_local]
            inc, _, ovf = msg.combined_send(
                sub, raw.dst_global, valid, lab[raw.src_local], "min",
                capacity=ctx.n_loc,
            )
            return inc, ovf

        if variant == "basic":
            return sparse(ctx)

        def dense(sub):
            # static broadcast of every label: pads carry the identity
            vals = jnp.where(gs.v_mask, lab, INF32)
            inc = sc.broadcast_combine(sub, gs.scatter_out, vals, "min")
            return inc, jnp.asarray(False)

        frac = compose.global_fraction(
            ctx, jnp.sum(active & gs.v_mask), jnp.sum(gs.v_mask)
        )
        result, _ = compose.switch_by_density(
            ctx, "wcc", frac, dense_threshold, dense, sparse
        )
        return result

    def init(pg):
        ids = pg.global_ids().astype(jnp.int32)
        return {
            "lab": jnp.where(pg.v_mask, ids, INF32),
            "active": pg.v_mask,
        }

    def step(ctx, gs, state, step_idx):
        lab, active = state["lab"], state["active"]
        inc, overflow = exchange(ctx, gs, lab, active)
        new = jnp.where(gs.v_mask, jnp.minimum(lab, inc), lab)
        new_active = new != lab
        halt = ~jnp.any(new_active)
        return {"lab": new, "active": new_active}, halt, overflow

    return VertexProgram(
        name=f"wcc:{variant}", init=init, step=step, extract=extract,
        max_steps=max_steps,
        meta={"algorithm": "wcc", "variant": variant,
              "dense_threshold": dense_threshold},
    )


def run(pg: PartitionedGraph, variant: str = "prop", max_steps: int = 10_000,
        backend: str = "vmap", mesh=None, mode=None, chunk_size: int = 64,
        dense_threshold: float = 0.1):
    prog = program(variant=variant, max_steps=max_steps,
                   dense_threshold=dense_threshold)
    res = engine.run_program(prog, pg, backend=backend, mesh=mesh, mode=mode,
                             chunk_size=chunk_size)
    return res.output, res
