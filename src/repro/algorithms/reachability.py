"""Multi-source reachability / BFS hop counts (directed frontier
expansion — unit-weight min-hop propagation over the CombinedMessage
channel, paper Table I).

Variants:
  - "basic": per-superstep CombinedMessage — frontier vertices send
             ``hop + 1`` to their out-neighbors, receivers keep the min.
             O(eccentricity) supersteps from the source.

Output: (n,) int32 BFS levels in old-id space (``UNREACHED`` = int32 max
for vertices the source cannot reach); ``reachable = hops != UNREACHED``.

The source vertex is the program's *query axis* (``query_init``):
``Engine.run_batch(prog, pg, sources)`` answers Q reachability queries —
the "which vertices can these users reach" fan-out shape — in one
compiled batched loop, each query halting independently the superstep
its frontier dies.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import message as msg
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import engine
from repro.pregel.program import VertexProgram

UNREACHED = jnp.iinfo(jnp.int32).max

VARIANTS = ("basic",)


def program(variant: str = "basic", *, source: int = 0,
            max_steps: int = 10_000) -> VertexProgram:
    """BFS reachability as a VertexProgram. Output: (n,) int32 hop counts
    in old-id space (UNREACHED where the source cannot reach)."""
    if variant not in VARIANTS:
        raise ValueError(variant)

    def query_init(pg, src_old):
        src_new = int(pg.new_of_old.arr[src_old])
        ids = pg.global_ids()
        at_src = ids == src_new
        return {"hop": jnp.where(at_src, 0, UNREACHED).astype(jnp.int32),
                "active": at_src}

    def init(pg):
        return query_init(pg, source)

    def step(ctx, gs, state, step_idx):
        hop, active = state["hop"], state["active"]
        raw = gs.raw_out
        valid = raw.mask & active[raw.src_local]
        # UNREACHED+1 would wrap; invalid lanes are masked, so clip first
        send_val = jnp.minimum(hop[raw.src_local], UNREACHED - 1) + 1
        inc, got, overflow = msg.combined_send(
            ctx, raw.dst_global, valid, send_val, "min",
            capacity=ctx.edge_capacity(ctx.n_loc),
        )
        new = jnp.where(gs.v_mask, jnp.minimum(hop, inc), hop)
        new_active = new < hop
        return (
            {"hop": new, "active": new_active},
            ~jnp.any(new_active),
            overflow,
        )

    def extract(pg, state):
        return pg.to_global(state["hop"])

    return VertexProgram(
        name=f"reach:{variant}", init=init, step=step, extract=extract,
        query_init=query_init, max_steps=max_steps,
        meta={"algorithm": "reach", "variant": variant, "source": source},
    )


def bfs_oracle(g, source: int) -> np.ndarray:
    """Host BFS levels (numpy frontier sweep) — the test oracle."""
    n = g.n
    hops = np.full(n, np.iinfo(np.int32).max, np.int32)
    hops[source] = 0
    src, dst = g.edges[:, 0], g.edges[:, 1]
    frontier = np.zeros(n, bool)
    frontier[source] = True
    level = 0
    while frontier.any():
        level += 1
        sel = frontier[src]
        nxt = np.zeros(n, bool)
        nxt[dst[sel]] = True
        nxt &= hops == np.iinfo(np.int32).max
        hops[nxt] = level
        frontier = nxt
    return hops


def run(pg: PartitionedGraph, source_old: int, variant: str = "basic",
        max_steps: int = 10_000, backend: str = "vmap", mesh=None,
        mode=None, chunk_size: int = 64):
    prog = program(variant=variant, source=source_old, max_steps=max_steps)
    res = engine.run_program(prog, pg, backend=backend, mesh=mesh, mode=mode,
                             chunk_size=chunk_size)
    return res.output, res
