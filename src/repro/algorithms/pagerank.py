"""PageRank (paper Fig. 1 / Table V top).

Variants:
  - "basic":   CombinedMessage channel (per-superstep sort-based routing,
               ids on the wire) — the standard-channel Fig. 1 program.
  - "scatter": ScatterCombine channel (static plan, no ids) — the paper's
               one-line optimization switch.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import aggregator as agg
from repro.core import message as msg
from repro.core import scatter_combine as sc
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import runtime


def run(pg: PartitionedGraph, iters: int = 30, variant: str = "scatter",
        damping: float = 0.85, backend: str = "vmap", mesh=None,
        use_kernel: bool = False, mode=None, chunk_size: int = 64):
    n = jnp.float32(pg.n)

    def step(ctx, gs, state, step_idx):
        pr = state["pr"]
        deg = jnp.maximum(gs.deg_out, 1).astype(jnp.float32)
        contrib = jnp.where(gs.deg_out > 0, pr / deg, 0.0)
        overflow = jnp.asarray(False)
        if variant == "scatter":
            incoming = sc.broadcast_combine(
                ctx, gs.scatter_out, contrib, "sum", use_kernel=use_kernel
            )
        elif variant == "basic":
            raw = gs.raw_out
            incoming, _, overflow = msg.combined_send(
                ctx,
                raw.dst_global,
                raw.mask,
                contrib[raw.src_local],
                "sum",
                capacity=ctx.n_loc,
            )
        else:
            raise ValueError(variant)
        sink = agg.aggregate(
            ctx, jnp.where((gs.deg_out == 0) & gs.v_mask, pr, 0.0), "sum"
        )
        new_pr = jnp.where(
            gs.v_mask, (1 - damping) / n + damping * (incoming + sink / n), 0.0
        )
        return {"pr": new_pr}, step_idx >= iters - 1, overflow

    state0 = {"pr": jnp.where(pg.v_mask, 1.0 / n, 0.0)}
    res = runtime.run_supersteps(pg, step, state0, max_steps=iters,
                                 backend=backend, mesh=mesh, mode=mode,
                                 chunk_size=chunk_size)
    return pg.to_global(res.state["pr"]), res
