"""PageRank (paper Fig. 1 / Table V top).

Variants:
  - "basic":    CombinedMessage channel (per-superstep sort-based routing,
                ids on the wire) — the standard-channel Fig. 1 program.
  - "scatter":  ScatterCombine channel (static plan, no ids) — the paper's
                one-line optimization switch.
  - "personal": personalized PageRank — the teleport (and sink) mass goes
                to a single source vertex instead of the uniform vector,
                over the same ScatterCombine channel. The source is the
                program's *query axis* (``query_init``):
                ``Engine.run_batch(prog, pg, sources)`` scores Q
                personalization vectors in one compiled batched loop —
                the per-user-ranking serving shape.

``program(variant=...)`` builds the declarative
:class:`~repro.pregel.program.VertexProgram`; ``run`` is the thin
one-shot wrapper over :class:`repro.pregel.engine.Engine`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import aggregator as agg
from repro.core import message as msg
from repro.core import scatter_combine as sc
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import engine
from repro.pregel.program import VertexProgram

VARIANTS = ("basic", "scatter", "personal")


def program(variant: str = "scatter", *, iters: int = 30,
            damping: float = 0.85, source: int = 0,
            use_kernel: bool = False) -> VertexProgram:
    """PageRank as a VertexProgram. Output: (n,) ranks in old-id space."""
    if variant not in VARIANTS:
        raise ValueError(variant)

    if variant == "personal":
        return _personal(iters=iters, damping=damping, source=source,
                         use_kernel=use_kernel)

    def init(pg):
        return {"pr": jnp.where(pg.v_mask, 1.0 / jnp.float32(pg.n), 0.0)}

    def step(ctx, gs, state, step_idx):
        # gs.n is a static field of the graph shard — the program stays
        # graph-agnostic (n is baked per compiled shape, not per program)
        n = jnp.float32(gs.n)
        pr = state["pr"]
        deg = jnp.maximum(gs.deg_out, 1).astype(jnp.float32)
        contrib = jnp.where(gs.deg_out > 0, pr / deg, 0.0)
        overflow = jnp.asarray(False)
        if variant == "scatter":
            incoming = sc.broadcast_combine(
                ctx, gs.scatter_out, contrib, "sum", use_kernel=use_kernel
            )
        else:
            raw = gs.raw_out
            incoming, _, overflow = msg.combined_send(
                ctx,
                raw.dst_global,
                raw.mask,
                contrib[raw.src_local],
                "sum",
                capacity=ctx.edge_capacity(ctx.n_loc),
            )
        sink = agg.aggregate(
            ctx, jnp.where((gs.deg_out == 0) & gs.v_mask, pr, 0.0), "sum"
        )
        new_pr = jnp.where(
            gs.v_mask, (1 - damping) / n + damping * (incoming + sink / n), 0.0
        )
        return {"pr": new_pr}, step_idx >= iters - 1, overflow

    def extract(pg, state):
        return pg.to_global(state["pr"])

    return VertexProgram(
        name=f"pagerank:{variant}", init=init, step=step, extract=extract,
        max_steps=iters,
        meta={"algorithm": "pagerank", "variant": variant, "iters": iters,
              "damping": damping},
    )


def _personal(*, iters: int, damping: float, source: int,
              use_kernel: bool) -> VertexProgram:
    """Personalized PageRank: teleport and sink mass concentrate on one
    source vertex. The source rides the *state* as a per-worker scalar
    (not a closure constant), so the step stays graph- and
    query-agnostic — exactly what lets run_batch vmap it over sources."""

    def query_init(pg, src_old):
        src_new = int(pg.new_of_old.arr[src_old])
        ids = pg.global_ids()
        e = ((ids == src_new) & pg.v_mask).astype(jnp.float32)
        return {"pr": e,
                "src": jnp.full((pg.num_workers,), src_new, jnp.int32)}

    def init(pg):
        return query_init(pg, source)

    def step(ctx, gs, state, step_idx):
        pr, src = state["pr"], state["src"]
        ids = (ctx.me() * ctx.n_loc
               + jnp.arange(ctx.n_loc, dtype=jnp.int32))
        e = ((ids == src) & gs.v_mask).astype(jnp.float32)
        deg = jnp.maximum(gs.deg_out, 1).astype(jnp.float32)
        contrib = jnp.where(gs.deg_out > 0, pr / deg, 0.0)
        incoming = sc.broadcast_combine(
            ctx, gs.scatter_out, contrib, "sum", use_kernel=use_kernel
        )
        sink = agg.aggregate(
            ctx, jnp.where((gs.deg_out == 0) & gs.v_mask, pr, 0.0), "sum"
        )
        new_pr = jnp.where(
            gs.v_mask, (1 - damping) * e + damping * (incoming + sink * e),
            0.0,
        )
        return {"pr": new_pr, "src": src}, step_idx >= iters - 1

    def extract(pg, state):
        return pg.to_global(state["pr"])

    return VertexProgram(
        name="pagerank:personal", init=init, step=step, extract=extract,
        query_init=query_init, max_steps=iters,
        meta={"algorithm": "pagerank", "variant": "personal",
              "iters": iters, "damping": damping, "source": source},
    )


def run(pg: PartitionedGraph, iters: int = 30, variant: str = "scatter",
        damping: float = 0.85, source: int = 0, backend: str = "vmap",
        mesh=None, use_kernel: bool = False, mode=None, chunk_size: int = 64):
    prog = program(variant=variant, iters=iters, damping=damping,
                   source=source, use_kernel=use_kernel)
    res = engine.run_program(prog, pg, backend=backend, mesh=mesh, mode=mode,
                             chunk_size=chunk_size)
    return res.output, res
