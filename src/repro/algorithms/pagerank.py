"""PageRank (paper Fig. 1 / Table V top).

Variants:
  - "basic":   CombinedMessage channel (per-superstep sort-based routing,
               ids on the wire) — the standard-channel Fig. 1 program.
  - "scatter": ScatterCombine channel (static plan, no ids) — the paper's
               one-line optimization switch.

``program(variant=...)`` builds the declarative
:class:`~repro.pregel.program.VertexProgram`; ``run`` is the thin
one-shot wrapper over :class:`repro.pregel.engine.Engine`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import aggregator as agg
from repro.core import message as msg
from repro.core import scatter_combine as sc
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import engine
from repro.pregel.program import VertexProgram

VARIANTS = ("basic", "scatter")


def program(variant: str = "scatter", *, iters: int = 30,
            damping: float = 0.85, use_kernel: bool = False) -> VertexProgram:
    """PageRank as a VertexProgram. Output: (n,) ranks in old-id space."""
    if variant not in VARIANTS:
        raise ValueError(variant)

    def init(pg):
        return {"pr": jnp.where(pg.v_mask, 1.0 / jnp.float32(pg.n), 0.0)}

    def step(ctx, gs, state, step_idx):
        # gs.n is a static field of the graph shard — the program stays
        # graph-agnostic (n is baked per compiled shape, not per program)
        n = jnp.float32(gs.n)
        pr = state["pr"]
        deg = jnp.maximum(gs.deg_out, 1).astype(jnp.float32)
        contrib = jnp.where(gs.deg_out > 0, pr / deg, 0.0)
        overflow = jnp.asarray(False)
        if variant == "scatter":
            incoming = sc.broadcast_combine(
                ctx, gs.scatter_out, contrib, "sum", use_kernel=use_kernel
            )
        else:
            raw = gs.raw_out
            incoming, _, overflow = msg.combined_send(
                ctx,
                raw.dst_global,
                raw.mask,
                contrib[raw.src_local],
                "sum",
                capacity=ctx.n_loc,
            )
        sink = agg.aggregate(
            ctx, jnp.where((gs.deg_out == 0) & gs.v_mask, pr, 0.0), "sum"
        )
        new_pr = jnp.where(
            gs.v_mask, (1 - damping) / n + damping * (incoming + sink / n), 0.0
        )
        return {"pr": new_pr}, step_idx >= iters - 1, overflow

    def extract(pg, state):
        return pg.to_global(state["pr"])

    return VertexProgram(
        name=f"pagerank:{variant}", init=init, step=step, extract=extract,
        max_steps=iters,
        meta={"algorithm": "pagerank", "variant": variant, "iters": iters,
              "damping": damping},
    )


def run(pg: PartitionedGraph, iters: int = 30, variant: str = "scatter",
        damping: float = 0.85, backend: str = "vmap", mesh=None,
        use_kernel: bool = False, mode=None, chunk_size: int = 64):
    prog = program(variant=variant, iters=iters, damping=damping,
                   use_kernel=use_kernel)
    res = engine.run_program(prog, pg, backend=backend, mesh=mesh, mode=mode,
                             chunk_size=chunk_size)
    return res.output, res
