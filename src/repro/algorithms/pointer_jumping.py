"""Pointer-Jumping (paper Table V middle): every vertex of a rooted forest
finds its root by repeated D[u] <- D[D[u]].

Variants:
  - "basic":   two DirectMessage rounds per superstep (ids both ways,
               no dedup) — Pregel's way.
  - "reqresp": the RequestRespond channel (dedup + positional replies).

``program(variant=..., parents=...)`` builds the declarative
:class:`~repro.pregel.program.VertexProgram` — the forest (old-id parent
array) is the problem input and is closed over by ``init``; ``run`` is
the thin one-shot wrapper over :class:`repro.pregel.engine.Engine`.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.algorithms import common
from repro.core import request_respond as rr
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import engine
from repro.pregel.program import VertexProgram

VARIANTS = ("basic", "reqresp")


def parents_to_local(pg: PartitionedGraph, parents_old: np.ndarray):
    """(n,) old-id parent array -> (W, n_loc) int32 in new-id space."""
    new = pg.new_of_old.arr
    flat = np.arange(pg.n_pad, dtype=np.int64)  # padding points to itself
    flat[new] = new[parents_old]
    return jnp.asarray(flat.reshape(pg.num_workers, pg.n_loc).astype(np.int32))


def program(variant: str = "reqresp", *, parents: np.ndarray,
            max_steps: int = 64) -> VertexProgram:
    """Pointer jumping as a VertexProgram. Output: (n,) root ids in
    *new*-id space (as the legacy ``run`` returned)."""
    if variant not in VARIANTS:
        raise ValueError(variant)

    def init(pg):
        return {"P": parents_to_local(pg, parents)}

    def query_init(pg, parents_q):
        # one query = one forest over the same vertex set (e.g. the
        # per-label pointer structures of a multi-label contraction)
        return {"P": parents_to_local(pg, parents_q)}

    def step(ctx, gs, state, step_idx):
        p = state["P"]
        if variant == "reqresp":
            grand, overflow = rr.request(
                ctx, p.reshape(-1), gs.v_mask.reshape(-1), p, capacity=ctx.n_loc
            )
        else:
            grand, overflow = common.direct_request_respond(
                ctx, p.reshape(-1), gs.v_mask.reshape(-1), p
            )
        newp = jnp.where(gs.v_mask, grand.reshape(p.shape), p)
        return {"P": newp}, jnp.all(newp == p), overflow

    def extract(pg, state):
        return pg.to_global(state["P"])

    return VertexProgram(
        name=f"pj:{variant}", init=init, step=step, extract=extract,
        query_init=query_init if variant == "reqresp" else None,
        max_steps=max_steps, meta={"algorithm": "pj", "variant": variant},
    )


def run(pg: PartitionedGraph, parents_old: np.ndarray, variant: str = "reqresp",
        max_steps: int = 64, backend: str = "vmap", mesh=None, mode=None,
        chunk_size: int = 64):
    prog = program(variant=variant, parents=parents_old, max_steps=max_steps)
    res = engine.run_program(prog, pg, backend=backend, mesh=mesh, mode=mode,
                             chunk_size=chunk_size)
    return res.output, res
