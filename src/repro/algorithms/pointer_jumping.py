"""Pointer-Jumping (paper Table V middle): every vertex of a rooted forest
finds its root by repeated D[u] <- D[D[u]].

Variants:
  - "basic":   two DirectMessage rounds per superstep (ids both ways,
               no dedup) — Pregel's way.
  - "reqresp": the RequestRespond channel (dedup + positional replies).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.algorithms import common
from repro.core import request_respond as rr
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import runtime


def parents_to_local(pg: PartitionedGraph, parents_old: np.ndarray):
    """(n,) old-id parent array -> (W, n_loc) int32 in new-id space."""
    new = pg.new_of_old.arr
    flat = np.arange(pg.n_pad, dtype=np.int64)  # padding points to itself
    flat[new] = new[parents_old]
    return jnp.asarray(flat.reshape(pg.num_workers, pg.n_loc).astype(np.int32))


def run(pg: PartitionedGraph, parents_old: np.ndarray, variant: str = "reqresp",
        max_steps: int = 64, backend: str = "vmap", mesh=None, mode=None,
        chunk_size: int = 64):
    p0 = parents_to_local(pg, parents_old)

    def step(ctx, gs, state, step_idx):
        p = state["P"]
        if variant == "reqresp":
            grand, overflow = rr.request(
                ctx, p.reshape(-1), gs.v_mask.reshape(-1), p, capacity=ctx.n_loc
            )
        elif variant == "basic":
            grand, overflow = common.direct_request_respond(
                ctx, p.reshape(-1), gs.v_mask.reshape(-1), p
            )
        else:
            raise ValueError(variant)
        newp = jnp.where(gs.v_mask, grand.reshape(p.shape), p)
        return {"P": newp}, jnp.all(newp == p), overflow

    res = runtime.run_supersteps(pg, step, {"P": p0}, max_steps=max_steps,
                                 backend=backend, mesh=mesh, mode=mode,
                                 chunk_size=chunk_size)
    roots_new = pg.to_global(res.state["P"])
    return roots_new, res
