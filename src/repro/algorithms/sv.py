"""Shiloach-Vishkin connected components (paper §III-C, §V, Tables VI).

The showcase for channel *composition*. Three communication patterns, each
with a baseline and an optimized channel:

  1. root test + pointer jumping  (D[D[u]]):   DirectMessage 2-phase  vs
     RequestRespond channel                     [load balance]
  2. neighbor minimum  (min D[e] over Nbr[u]):  CombinedMessage per edge vs
     ScatterCombine channel                     [neighborhood traffic]
  3. remote min-update (D[D[u]] <?= t):         CombinedMessage (min)
     in all variants                            [congestion]

variants "basic" | "reqresp" | "scatter" | "both" are exactly the paper's
programs 2-5 in Table VI; "monolithic" is the Pregel baseline with one
padded message type.

variant "composed" is the paper's §V case study built on the composition
layer (``repro.core.compose``): one :class:`~repro.core.compose.Stacked`
channel bundles the request-respond pointer lookups, the min-combiner
scatter-combine neighbor minimum, the min-combined tree-merge message,
*and* a propagation-style full pointer jumping that shortcuts every tree
to a star inside the superstep (a device-side fixpoint, the same local
iteration trick the propagation channel uses) — so the composed program
needs fewer global rounds AND less traffic than any single-channel
variant, the paper's headline 2.20x composition result. Traffic is
attributed per component under namespaced keys (``sv/pointer/request``,
``sv/neighbor_min``, ``sv/merge``, ``sv/jump``, ...), and the stack
declares its full registry entry set to the runtime (the composed
VertexProgram carries ``channels=<stack>``, so the runtime skips the
eval_shape dry trace entirely).

All variants converge to D[u] = min vertex id of u's component, so their
final states are bit-identical (tests/test_compose.py relies on this).
The graph must be symmetrized.

``program(variant=...)`` builds the declarative
:class:`~repro.pregel.program.VertexProgram`; ``run`` is the thin
one-shot wrapper over :class:`repro.pregel.engine.Engine`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.algorithms import common
from repro.core import compose
from repro.core import message as msg
from repro.core import request_respond as rr
from repro.core import scatter_combine as sc
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import engine
from repro.pregel.program import VertexProgram

INF32 = jnp.iinfo(jnp.int32).max

VARIANTS = ("basic", "reqresp", "scatter", "both", "monolithic", "composed")


def composed_channels(use_kernel: bool = False) -> compose.Stacked:
    """The §V composition: the three optimized channels plus full jumping,
    stacked under the ``sv/`` namespace with per-component attribution."""

    def neighbor_min(ctx, name, plan, vals):
        return sc.broadcast_combine(ctx, plan, vals, "min",
                                    use_kernel=use_kernel, name=name)

    return compose.stacked(
        "sv",
        pointer=compose.request_component(),
        neighbor_min=compose.Component(neighbor_min),
        merge=compose.combined_component("min"),
        jump=common.jump_component(),
    )


def _composed_step(chan: compose.Stacked):
    """One composed superstep: hook by neighbor minimum, then shortcut all
    trees to stars (full jumping) before the next global round."""

    def step(ctx, gs, state, step_idx):
        d = state["D"]

        # 1. is my parent a root?  (grand == D[u]) — request-respond.
        # After step 4's full jumping every tree is a star, so this is
        # invariantly true; the lookup is kept (rather than optimized
        # away) because it is part of the paper's composed S-V program —
        # its round and bytes are costs that program genuinely pays.
        grand, ovf1 = chan.call(ctx, "pointer", d, gs.v_mask, d,
                                capacity=ctx.n_loc)
        parent_is_root = grand == d

        # 2. minimum neighbor pointer t — min-combiner scatter-combine
        t = chan.call(ctx, "neighbor_min", gs.scatter_out, d)

        # 3. tree merging: send t to the root D[u] with a min-combiner
        cond = gs.v_mask & parent_is_root & (t < d)
        minval, got, ovf3 = chan.call(ctx, "merge", d, cond, t,
                                      capacity=ctx.n_loc)
        d1 = jnp.where(got & gs.v_mask, jnp.minimum(d, minval), d)

        # 4. full pointer jumping: D[u] <- root(u) (propagation-style
        #    device-side fixpoint — trees become stars within the step)
        d2, _ = chan.call(ctx, "jump", d1, gs.v_mask)
        d2 = jnp.where(gs.v_mask, d2, d1)

        halt = jnp.all(d2 == d)
        return {"D": d2}, halt, ovf1 | ovf3

    return step


def _init(pg):
    return {"D": pg.global_ids().astype(jnp.int32)}  # D[u] = u (pads too)


def _extract(pg, state):
    return pg.to_global(state["D"])


def program(variant: str = "both", *, max_steps: int = 200,
            use_kernel: bool = False) -> VertexProgram:
    """S-V as a VertexProgram. Output: (n,) component labels (min member
    id) in old-id space."""
    if variant not in VARIANTS:
        raise ValueError(variant)
    meta = {"algorithm": "sv", "variant": variant}

    if variant == "composed":
        chan = composed_channels(use_kernel=use_kernel)
        return VertexProgram(
            name="sv:composed", init=_init, step=_composed_step(chan),
            extract=_extract, channels=chan, max_steps=max_steps, meta=meta,
        )

    use_rr = variant in ("reqresp", "both")
    use_sc = variant in ("scatter", "both")
    monolithic = variant == "monolithic"

    def ask(ctx, gs, dst_per_vertex, vals):
        """D[dst] for every local vertex, via the selected channel."""
        if use_rr:
            resp, ovf = rr.request(
                ctx, dst_per_vertex, gs.v_mask, vals, capacity=ctx.n_loc
            )
        else:
            resp, ovf = common.direct_request_respond(
                ctx, dst_per_vertex, gs.v_mask, vals
            )
        return resp, ovf

    def neighbor_min(ctx, gs, vals):
        """min over neighbors' vals, via the selected channel."""
        if use_sc:
            t = sc.broadcast_combine(ctx, gs.scatter_out, vals, "min",
                                     use_kernel=use_kernel)
            return t, jnp.asarray(False)
        raw = gs.raw_out
        if monolithic:
            # Pregel with an inapplicable global combiner: one message per
            # edge, combined only at the receiver (paper §V-A analysis).
            deliv = msg.direct_send(
                ctx, raw.dst_global, raw.mask,
                {"v": vals[raw.src_local]}, capacity=raw.e_cap,
                name="mono_message",
            )
            from repro.kernels import ops as kops
            inc = kops.segment_combine(
                jnp.where(deliv.mask, deliv.payload["v"], INF32),
                deliv.dst_local, ctx.n_loc, "min", use_kernel=False)
            return inc, deliv.overflow
        inc, got, ovf = msg.combined_send(
            ctx, raw.dst_global, raw.mask, vals[raw.src_local], "min",
            capacity=ctx.n_loc,
        )
        return jnp.where(got, inc, INF32), ovf

    def step(ctx, gs, state, step_idx):
        d = state["D"]

        # 1. is my parent a root?  (grand == D[u])
        grand, ovf1 = ask(ctx, gs, d, d)
        parent_is_root = grand == d

        # 2. minimum neighbor pointer t
        t, ovf2 = neighbor_min(ctx, gs, d)

        # 3. tree merging: send t to the root D[u] with a min-combiner
        cond = gs.v_mask & parent_is_root & (t < d)
        if monolithic:
            deliv = msg.direct_send(ctx, d, cond, {"t": t},
                                    capacity=ctx.n_loc, name="mono_message")
            from repro.kernels import ops as kops
            # receiver-side combine over unsorted delivery order: always
            # the reference path (kernel wants sorted segment ids)
            minval = kops.segment_combine(
                jnp.where(deliv.mask, deliv.payload["t"], INF32),
                deliv.dst_local, ctx.n_loc, "min", use_kernel=False)
            got = minval != INF32
            ovf3 = deliv.overflow
        else:
            minval, got, ovf3 = msg.combined_send(
                ctx, d, cond, t, "min", capacity=ctx.n_loc,
                name="merge_message"
            )
        d1 = jnp.where(got & gs.v_mask, jnp.minimum(d, minval), d)

        # 4. pointer jumping: D[u] <- D[D[u]] (one hop, reads merged values)
        grand2, ovf4 = ask(ctx, gs, d1, d1)
        d2 = jnp.where(gs.v_mask, grand2, d1)

        halt = jnp.all(d2 == d)
        overflow = ovf1 | ovf2 | ovf3 | ovf4
        return {"D": d2}, halt, overflow

    return VertexProgram(
        name=f"sv:{variant}", init=_init, step=step, extract=_extract,
        max_steps=max_steps, meta=meta,
    )


def run(pg: PartitionedGraph, variant: str = "both", max_steps: int = 200,
        backend: str = "vmap", mesh=None, use_kernel: bool = False,
        mode=None, chunk_size: int = 64, route_impl=None):
    prog = program(variant=variant, max_steps=max_steps,
                   use_kernel=use_kernel)
    res = engine.run_program(prog, pg, backend=backend, mesh=mesh, mode=mode,
                             chunk_size=chunk_size, route_impl=route_impl)
    return res.output, res
