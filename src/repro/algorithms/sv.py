"""Shiloach-Vishkin connected components (paper §III-C, Table VI).

The showcase for channel *composition*. Three communication patterns, each
with a baseline and an optimized channel:

  1. root test + pointer jumping  (D[D[u]]):   DirectMessage 2-phase  vs
     RequestRespond channel                     [load balance]
  2. neighbor minimum  (min D[e] over Nbr[u]):  CombinedMessage per edge vs
     ScatterCombine channel                     [neighborhood traffic]
  3. remote min-update (D[D[u]] <?= t):         CombinedMessage (min)
     in all variants                            [congestion]

variants: "basic" | "reqresp" | "scatter" | "both" — exactly the paper's
programs 2-5 in Table VI. The graph must be symmetrized.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.algorithms import common
from repro.core import message as msg
from repro.core import request_respond as rr
from repro.core import scatter_combine as sc
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import runtime

INF32 = jnp.iinfo(jnp.int32).max


def run(pg: PartitionedGraph, variant: str = "both", max_steps: int = 200,
        backend: str = "vmap", mesh=None, use_kernel: bool = False,
        mode=None, chunk_size: int = 64):
    use_rr = variant in ("reqresp", "both")
    use_sc = variant in ("scatter", "both")
    monolithic = variant == "monolithic"
    if variant not in ("basic", "reqresp", "scatter", "both", "monolithic"):
        raise ValueError(variant)

    def ask(ctx, gs, dst_per_vertex, vals):
        """D[dst] for every local vertex, via the selected channel."""
        if use_rr:
            resp, ovf = rr.request(
                ctx, dst_per_vertex, gs.v_mask, vals, capacity=ctx.n_loc
            )
        else:
            resp, ovf = common.direct_request_respond(
                ctx, dst_per_vertex, gs.v_mask, vals
            )
        return resp, ovf

    def neighbor_min(ctx, gs, vals):
        """min over neighbors' vals, via the selected channel."""
        if use_sc:
            t = sc.broadcast_combine(ctx, gs.scatter_out, vals, "min",
                                     use_kernel=use_kernel)
            return t, jnp.asarray(False)
        raw = gs.raw_out
        if monolithic:
            # Pregel with an inapplicable global combiner: one message per
            # edge, combined only at the receiver (paper §V-A analysis).
            deliv = msg.direct_send(
                ctx, raw.dst_global, raw.mask,
                {"v": vals[raw.src_local]}, capacity=raw.e_cap,
                name="mono_message",
            )
            from repro.kernels import ops as kops
            inc = kops.segment_combine(
                jnp.where(deliv.mask, deliv.payload["v"], INF32),
                deliv.dst_local, ctx.n_loc, "min")
            return inc, deliv.overflow
        inc, got, ovf = msg.combined_send(
            ctx, raw.dst_global, raw.mask, vals[raw.src_local], "min",
            capacity=ctx.n_loc,
        )
        return jnp.where(got, inc, INF32), ovf

    def step(ctx, gs, state, step_idx):
        d = state["D"]
        gid = ctx.me() * ctx.n_loc + jnp.arange(ctx.n_loc, dtype=jnp.int32)

        # 1. is my parent a root?  (grand == D[u])
        grand, ovf1 = ask(ctx, gs, d, d)
        parent_is_root = grand == d

        # 2. minimum neighbor pointer t
        t, ovf2 = neighbor_min(ctx, gs, d)

        # 3. tree merging: send t to the root D[u] with a min-combiner
        cond = gs.v_mask & parent_is_root & (t < d)
        if monolithic:
            deliv = msg.direct_send(ctx, d, cond, {"t": t},
                                    capacity=ctx.n_loc, name="mono_message")
            from repro.kernels import ops as kops
            minval = kops.segment_combine(
                jnp.where(deliv.mask, deliv.payload["t"], INF32),
                deliv.dst_local, ctx.n_loc, "min")
            got = minval != INF32
            ovf3 = deliv.overflow
        else:
            minval, got, ovf3 = msg.combined_send(
                ctx, d, cond, t, "min", capacity=ctx.n_loc,
                name="merge_message"
            )
        d1 = jnp.where(got & gs.v_mask, jnp.minimum(d, minval), d)

        # 4. pointer jumping: D[u] <- D[D[u]] (one hop, reads merged values)
        grand2, ovf4 = ask(ctx, gs, d1, d1)
        d2 = jnp.where(gs.v_mask, grand2, d1)

        halt = jnp.all(d2 == d)
        overflow = ovf1 | ovf2 | ovf3 | ovf4
        return {"D": d2}, halt, overflow

    ids = pg.global_ids().astype(jnp.int32)
    state0 = {"D": jnp.where(pg.v_mask, ids, ids)}  # D[u] = u (pads too)
    res = runtime.run_supersteps(pg, step, state0, max_steps=max_steps,
                                 backend=backend, mesh=mesh, mode=mode,
                                 chunk_size=chunk_size)
    return pg.to_global(res.state["D"]), res
