"""The algorithm registry: every paper program as data.

Each algorithm module exports ``program(variant=..., **knobs) ->
VertexProgram``; this package assembles them into ``REGISTRY`` — a flat
``"algorithm:variant"`` table of :class:`ProgramSpec` entries that also
carry the *problem recipe*: which graph plans the program needs
(``build``), how to generate a benchmark/test instance of its problem
(``make_graph``/``make_inputs``), and how to verify an answer against
the host oracles (``check``). The ``python -m repro`` CLI, the
registry-parametrized test sweep and the benchmark tables are all driven
from here, so adding a variant to an algorithm module plus one REGISTRY
line makes it appear everywhere.

    from repro.algorithms import REGISTRY, get_program
    spec = REGISTRY["wcc:switch"]
    prog = get_program("wcc:switch")          # memoized — share an
                                              # instance to share compiles

``get_program`` returns the same VertexProgram instance for the same
(key, knobs), which is what makes Engine compile caches hit across call
sites (programs hash by identity).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.algorithms import (msf, pagerank, pointer_jumping, reachability,
                              scc, sssp, sv, wcc)
from repro.graph import generators as gen, oracles
from repro.pregel.program import VertexProgram

ALL_PLANS = ("scatter_out", "scatter_in", "prop_out", "prop_in",
             "raw_out", "raw_in")


def _canon(x):
    first: Dict[Any, int] = {}
    return np.array([first.setdefault(v, i) for i, v in enumerate(x)])


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """One registry entry: a program factory plus its problem recipe.

    factory: ``factory(**knobs) -> VertexProgram`` (variant pre-bound).
    build: the ``partition_graph(build=...)`` plans the program needs.
    make_graph: ``(scale, seed) -> EdgeList`` default problem graph.
    make_inputs: optional ``(graph, seed) -> knobs`` problem inputs that
      must reach the factory (a SSSP source, a pointer-jumping forest).
    check: optional ``(graph, pg, res, inputs) -> None`` — asserts a
      default-knob run's ``res.output`` against the host oracle.
    legacy: ``(pg, inputs, mode, chunk_size) -> (output, RunResult)`` via
      the backward-compatible module ``run()`` wrapper — the bit-parity
      reference for registry-driven runs.
    make_queries: optional ``(graph, seed, q) -> list`` of Q query values
      for the program's query axis (``Engine.run_batch``) — set iff the
      factory's programs declare ``query_init`` (the spec is *batched*).
    query_knob: the factory knob one query value binds to (e.g.
      ``"source"``) — how a batched query is replayed as a single run.
    channel_class: the data-plane family the program's per-superstep
      communication belongs to — ``"static"`` (plan-driven channels:
      scatter-combine / propagation, fixed wire layout) or ``"routed"``
      (dynamic bucket-routed channels: Direct/Combined message,
      RequestRespond — the ones the union-frontier batching shares one
      route pass across under ``route_batch="union"``).
    test_scale: graph scale the test sweep / CLI default to.
    """

    key: str
    algorithm: str
    variant: str
    factory: Callable[..., VertexProgram]
    build: Tuple[str, ...]
    make_graph: Callable[[int, int], gen.EdgeList]
    make_inputs: Optional[Callable] = None
    check: Optional[Callable] = None
    legacy: Optional[Callable] = None
    make_queries: Optional[Callable] = None
    query_knob: Optional[str] = None
    channel_class: str = "static"
    test_scale: int = 8

    def inputs(self, graph: gen.EdgeList, seed: int = 0) -> Dict[str, Any]:
        return dict(self.make_inputs(graph, seed)) if self.make_inputs else {}

    def queries(self, graph: gen.EdgeList, seed: int = 0,
                q: int = 8) -> list:
        if self.make_queries is None:
            raise ValueError(f"{self.key} has no query axis")
        return list(self.make_queries(graph, seed, q))

    def stream(self, graph: gen.EdgeList, seed: int = 0, q: int = 8,
               rate: float = 1.0) -> list:
        """A serving workload for the program's query axis:
        ``(arrival_superstep, query)`` pairs — the spec's deterministic
        query generator zipped with a seeded Poisson arrival process at
        ``rate`` expected arrivals per superstep. Feed it to
        ``QueryQueue.from_schedule`` / ``Engine.serve``."""
        from repro.pregel.serve import poisson_arrivals

        return list(zip(poisson_arrivals(q, rate, seed),
                        self.queries(graph, seed, q)))

    def make(self, graph: Optional[gen.EdgeList] = None, seed: int = 0,
             **knobs) -> VertexProgram:
        """Build the program, threading generated problem inputs through
        (explicit ``knobs`` win)."""
        kw = self.inputs(graph, seed) if graph is not None else {}
        kw.update(knobs)
        return self.factory(**kw)


# --- default problem instances (deterministic in (scale, seed)) ------------


def _sym_rmat(scale, seed):
    return gen.rmat(scale, edge_factor=4, seed=2 + seed).symmetrized()


def _directed_rmat(scale, seed):
    return gen.rmat(scale, edge_factor=4, seed=2 + seed)


def _weighted_rmat(scale, seed):
    return gen.rmat(scale, edge_factor=4, seed=5 + seed, weighted=True)


def _weighted_sym_rmat(scale, seed):
    return gen.rmat(scale, edge_factor=4, seed=9 + seed,
                    weighted=True).symmetrized()


def _scc_rmat(scale, seed):
    return gen.rmat(scale, edge_factor=3, seed=7 + seed)


def _forest_graph(scale, seed):
    n = 1 << scale
    return gen.EdgeList(n, np.zeros((0, 2), np.int64), None, True, "pj")


def _forest_inputs(graph, seed):
    return {"parents": gen.random_tree_parents(graph.n, seed=1 + seed)}


def _random_sources(graph, seed, q):
    """Q distinct source vertices — the default query batch (landmark
    distances / reachability fan-out / per-user personalization)."""
    rng = np.random.default_rng(33 + seed)
    return rng.choice(graph.n, size=min(q, graph.n),
                      replace=False).astype(int).tolist()


def _forest_queries(graph, seed, q):
    """Q distinct random forests over the same vertex set — the
    pointer-jumping query batch (per-label pointer structures)."""
    return [gen.random_tree_parents(graph.n, seed=100 + seed * 997 + i)
            for i in range(q)]


# --- oracle checks ----------------------------------------------------------


def _check_components(graph, pg, res, inputs):
    truth = gen.components_ground_truth(graph)
    np.testing.assert_array_equal(_canon(res.output), _canon(truth))


def _check_pagerank(graph, pg, res, inputs):
    want = oracles.pagerank_oracle(graph, iters=res.steps)
    np.testing.assert_allclose(res.output, want, rtol=1e-4, atol=1e-7)


def _check_ppr(graph, pg, res, inputs):
    want = oracles.personalized_pagerank_oracle(
        graph, source=inputs.get("source", 0), iters=res.steps)
    np.testing.assert_allclose(res.output, want, rtol=1e-4, atol=1e-7)


def _check_reach(graph, pg, res, inputs):
    want = reachability.bfs_oracle(graph, source=inputs.get("source", 0))
    np.testing.assert_array_equal(res.output, want)


def _check_sssp(graph, pg, res, inputs):
    want = oracles.sssp_oracle(graph, source=inputs.get("source", 0))
    finite = ~np.isinf(want)
    np.testing.assert_allclose(res.output[finite], want[finite], rtol=1e-5)
    assert np.isinf(res.output[~finite]).all()


def _check_scc(graph, pg, res, inputs):
    want = oracles.scc_oracle(graph)
    np.testing.assert_array_equal(_canon(res.output), _canon(want))


def _check_msf(graph, pg, res, inputs):
    want_w = oracles.msf_weight_oracle(graph)
    assert abs(res.output["weight"] - want_w) < 1e-2
    truth = gen.components_ground_truth(graph)
    assert res.output["edges"] == graph.n - len(set(truth.tolist()))


def _check_pj(graph, pg, res, inputs):
    p = inputs["parents"].copy()
    for _ in range(graph.n):
        nxt = p[p]
        if (nxt == p).all():
            break
        p = nxt
    np.testing.assert_array_equal(res.output, pg.new_of_old.arr[p])
    assert res.halted


# --- the registry -----------------------------------------------------------


def _bind(program_fn, variant):
    return lambda **kw: program_fn(variant=variant, **kw)


def _specs():
    def add(out, algorithm, variant, program_fn, legacy, **kw):
        key = f"{algorithm}:{variant}"
        out[key] = ProgramSpec(
            key=key, algorithm=algorithm, variant=variant,
            factory=_bind(program_fn, variant),
            legacy=legacy, **kw,
        )

    out: Dict[str, ProgramSpec] = {}

    for v in wcc.VARIANTS:
        add(out, "wcc", v, wcc.program,
            lambda pg, inputs, mode, cs, _v=v: wcc.run(
                pg, variant=_v, mode=mode, chunk_size=cs),
            build=("scatter_out", "prop_out", "raw_out"),
            make_graph=_sym_rmat, check=_check_components)

    for v in sv.VARIANTS:
        add(out, "sv", v, sv.program,
            lambda pg, inputs, mode, cs, _v=v: sv.run(
                pg, variant=_v, mode=mode, chunk_size=cs),
            build=("scatter_out", "prop_out", "raw_out"),
            make_graph=_sym_rmat, check=_check_components)

    for v in pagerank.VARIANTS:
        if v == "personal":
            continue  # registered below with its query-axis recipe
        add(out, "pagerank", v, pagerank.program,
            lambda pg, inputs, mode, cs, _v=v: pagerank.run(
                pg, variant=_v, mode=mode, chunk_size=cs),
            build=("scatter_out", "raw_out"),
            make_graph=_directed_rmat, check=_check_pagerank)

    add(out, "pagerank", "personal", pagerank.program,
        lambda pg, inputs, mode, cs: pagerank.run(
            pg, variant="personal", source=inputs.get("source", 0),
            mode=mode, chunk_size=cs),
        build=("scatter_out",),
        make_graph=_directed_rmat,
        make_inputs=lambda graph, seed: {"source": 0},
        check=_check_ppr,
        make_queries=_random_sources, query_knob="source",
        channel_class="static")

    for v in sssp.VARIANTS:
        add(out, "sssp", v, sssp.program,
            lambda pg, inputs, mode, cs, _v=v: sssp.run(
                pg, inputs.get("source", 0), variant=_v, mode=mode,
                chunk_size=cs),
            build=("prop_out", "raw_out"),
            make_graph=_weighted_rmat,
            make_inputs=lambda graph, seed: {"source": 0},
            check=_check_sssp,
            make_queries=_random_sources, query_knob="source",
            channel_class="routed" if v == "basic" else "static")

    for v in reachability.VARIANTS:
        add(out, "reach", v, reachability.program,
            lambda pg, inputs, mode, cs, _v=v: reachability.run(
                pg, inputs.get("source", 0), variant=_v, mode=mode,
                chunk_size=cs),
            build=("raw_out",),
            make_graph=_directed_rmat,
            make_inputs=lambda graph, seed: {"source": 0},
            check=_check_reach,
            make_queries=_random_sources, query_knob="source",
            channel_class="routed")

    for v in msf.VARIANTS:
        add(out, "msf", v, msf.program,
            lambda pg, inputs, mode, cs, _v=v: msf.run(
                pg, variant=_v, mode=mode, chunk_size=cs),
            build=("raw_out",),
            make_graph=_weighted_sym_rmat, check=_check_msf, test_scale=7)

    for v in scc.VARIANTS:
        add(out, "scc", v, scc.program,
            lambda pg, inputs, mode, cs, _v=v: scc.run(
                pg, variant=_v, mode=mode, chunk_size=cs),
            build=ALL_PLANS,
            make_graph=_scc_rmat, check=_check_scc, test_scale=7)

    for v in pointer_jumping.VARIANTS:
        # the reqresp variant carries a query axis: one query = one
        # forest over the same vertex set (distinct random trees)
        batched = v == "reqresp"
        add(out, "pj", v, pointer_jumping.program,
            lambda pg, inputs, mode, cs, _v=v: pointer_jumping.run(
                pg, inputs["parents"], variant=_v, mode=mode, chunk_size=cs),
            build=(),
            make_graph=_forest_graph, make_inputs=_forest_inputs,
            check=_check_pj, test_scale=9,
            make_queries=_forest_queries if batched else None,
            query_knob="parents" if batched else None,
            channel_class="routed")

    return out


REGISTRY: Dict[str, ProgramSpec] = _specs()

#: the variant ``python -m repro run <algorithm>`` picks when no variant
#: is given — each algorithm's optimized-channel showcase
DEFAULT_VARIANT: Dict[str, str] = {
    "wcc": "prop",
    "sv": "both",
    "msf": "channels",
    "scc": "prop",
    "sssp": "basic",
    "pagerank": "scatter",
    "pj": "reqresp",
    "reach": "basic",
}

ALGORITHMS: Tuple[str, ...] = tuple(sorted(DEFAULT_VARIANT))

#: specs with a query axis — what ``Engine.run_batch`` / the batched
#: parity sweep / ``python -m repro bench-batch`` iterate over
BATCHED: Tuple[str, ...] = tuple(
    sorted(k for k, s in REGISTRY.items() if s.make_queries is not None))

#: the abstract channel kinds a program may declare — the planner's
#: decision space (``repro.plan``) is keyed on this family, not on the
#: concrete channel types a variant happens to instantiate
CHANNEL_CLASSES: Tuple[str, ...] = ("static", "routed")


def channel_class_of(program_name: str) -> str:
    """The abstract data-plane family a registered program's channels
    lower from — the registry surface ``repro.plan.features`` consults.
    Unregistered names default to ``"static"`` (plan-driven channels
    need no routing decisions, so the default is the inert one)."""
    spec = REGISTRY.get(program_name)
    return spec.channel_class if spec is not None else "static"


def resolve(name: str) -> ProgramSpec:
    """``"wcc"`` (default variant) or ``"wcc:switch"`` -> ProgramSpec."""
    key = name if ":" in name else f"{name}:{DEFAULT_VARIANT.get(name, '')}"
    try:
        return REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown program {name!r}; registered: {', '.join(sorted(REGISTRY))}"
        ) from None


# memo value keeps the knobs alive alongside the program, so id()-keyed
# array knobs can never be recycled onto a different array
_PROGRAMS: Dict[Tuple, Tuple[VertexProgram, Dict[str, Any]]] = {}


def get_program(key: str, **knobs) -> VertexProgram:
    """Memoized program lookup: the same (key, knobs) returns the *same*
    VertexProgram instance, so Engine compile caches hit across call
    sites. Array knobs (e.g. a pointer-jumping parents forest) memoize
    by object identity; other unhashable knobs skip the memo."""
    spec = resolve(key)
    items = tuple(sorted(
        (k, id(v) if isinstance(v, np.ndarray) else v)
        for k, v in knobs.items()))
    try:
        memo_key = (spec.key, items)
        hash(memo_key)
    except TypeError:
        return spec.factory(**knobs)
    entry = _PROGRAMS.get(memo_key)
    if entry is None:
        entry = _PROGRAMS[memo_key] = (spec.factory(**knobs), dict(knobs))
    return entry[0]
