"""Min-Label SCC (Yan et al. [30]; paper Table VII).

Iterative rounds of: trivial-SCC removal, forward min-label propagation
(along out-edges), backward min-label propagation (along in-edges); the
vertices with F == B form the SCC of that label and freeze.

Variants:
  - "basic": forward/backward phases via per-superstep CombinedMessage.
  - "prop":  forward/backward phases via the Propagation channel — the
             paper's 'quick fix not possible in any existing system'.

``program(variant=...)`` builds the declarative
:class:`~repro.pregel.program.VertexProgram`; ``run`` is the thin
one-shot wrapper over :class:`repro.pregel.engine.Engine`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.algorithms import common
from repro.core import compose
from repro.core import propagation as prop
from repro.core import scatter_combine as sc
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import engine
from repro.pregel.program import VertexProgram

INF32 = jnp.iinfo(jnp.int32).max

VARIANTS = ("basic", "prop")


def program(variant: str = "prop", *, max_steps: int = 500) -> VertexProgram:
    """Min-label SCC as a VertexProgram. Output: (n,) SCC labels (min
    member id) in old-id space. The graph must be built with
    scatter_out+scatter_in and (prop_out+prop_in for "prop") or
    (raw_out+raw_in for "basic") on the DIRECTED graph."""
    if variant not in VARIANTS:
        raise ValueError(variant)

    def min_label(ctx, gs, alive, direction):
        ids = ctx.me() * ctx.n_loc + jnp.arange(ctx.n_loc, dtype=jnp.int32)
        lab0 = jnp.where(alive, ids, INF32)
        # propagate() works on 2-D (n_loc, D) internally — broadcast masks.
        amask = lambda lab: alive.reshape(alive.shape + (1,) * (lab.ndim - 1))
        mask_frozen = lambda lab: jnp.where(amask(lab), lab, INF32)
        upd = lambda lab, inc: jnp.where(amask(lab), jnp.minimum(lab, inc), lab)
        if variant == "prop":
            plan = gs.prop_out if direction == "fwd" else gs.prop_in
            lab, rounds, iters = prop.propagate(
                ctx, plan, lab0, "min", update=upd, src_values=mask_frozen,
                name=f"propagation/{direction}",
            )
            return lab, iters
        raw = gs.raw_out if direction == "fwd" else gs.raw_in
        upd3 = lambda lab, inc, got: jnp.where(alive, jnp.minimum(lab, inc), lab)
        lab, iters = common.cm_propagate(
            ctx, raw, lab0, "min", active0=alive, update=upd3,
            name=f"basic_propagation/{direction}",
        )
        return lab, iters

    def step(ctx, gs, state, step_idx):
        alive, scc_lab = state["alive"], state["scc"]
        gid = ctx.me() * ctx.n_loc + jnp.arange(ctx.n_loc, dtype=jnp.int32)

        # trivial removal: alive in/out degree == 0 => own SCC. The two
        # scatter-combines are independent, so the composition layer
        # merges them into a single collective round (paper §V).
        alive_f = alive.astype(jnp.float32)
        in_alive, out_alive = compose.fused_exchange(ctx, [
            sc.plan_broadcast_combine(ctx, gs.scatter_out, alive_f, "sum",
                                      name="degree/out"),
            sc.plan_broadcast_combine(ctx, gs.scatter_in, alive_f, "sum",
                                      name="degree/in"),
        ])
        trivial = alive & ((in_alive == 0) | (out_alive == 0))
        scc_lab = jnp.where(trivial, gid, scc_lab)
        alive = alive & ~trivial

        # forward/backward min-label among alive
        f_lab, it_f = min_label(ctx, gs, alive, "fwd")
        b_lab, it_b = min_label(ctx, gs, alive, "bwd")
        found = alive & (f_lab == b_lab) & (f_lab != INF32)
        scc_lab = jnp.where(found, f_lab, scc_lab)
        alive = alive & ~found

        halt = ~jnp.any(alive)
        return {
            "alive": alive,
            "scc": scc_lab,
            "iters": state["iters"] + it_f + it_b,
        }, halt

    def init(pg):
        return {
            "alive": pg.v_mask,
            "scc": jnp.full((pg.num_workers, pg.n_loc), -1, jnp.int32),
            "iters": jnp.zeros((pg.num_workers,), jnp.int32),
        }

    def extract(pg, state):
        return pg.to_global(state["scc"])

    return VertexProgram(
        name=f"scc:{variant}", init=init, step=step, extract=extract,
        max_steps=max_steps, meta={"algorithm": "scc", "variant": variant},
    )


def run(pg: PartitionedGraph, variant: str = "prop", max_steps: int = 500,
        backend: str = "vmap", mesh=None, mode=None, chunk_size: int = 64):
    prog = program(variant=variant, max_steps=max_steps)
    res = engine.run_program(prog, pg, backend=backend, mesh=mesh, mode=mode,
                             chunk_size=chunk_size)
    return res.output, res
