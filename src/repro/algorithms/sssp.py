"""Single-source shortest paths (weighted Bellman-Ford flavor) — the
weighted generalization of the paper's §IV-C3 propagation channel
(`edge_transform = dist + w`), beyond the paper's min-label tables.

Variants:
  - "basic": per-superstep CombinedMessage from active (improved) vertices.
  - "prop":  Propagation channel with edge_transform = dist + w — the
             channel generalizes beyond min-label propagation.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import message as msg
from repro.core import propagation as prop
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import runtime

INF = jnp.float32(np.inf)


def run(pg: PartitionedGraph, source_old: int, variant: str = "basic",
        max_steps: int = 10_000, backend: str = "vmap", mesh=None,
        mode=None, chunk_size: int = 64):
    src_new = int(pg.new_of_old.arr[source_old])
    ids = pg.global_ids()
    dist0 = jnp.where(ids == src_new, 0.0, INF).astype(jnp.float32)

    add_w = lambda v, w: v + (w[:, None] if v.ndim == 2 else w)

    if variant == "prop":

        def step(ctx, gs, state, step_idx):
            dist, rounds, iters = prop.propagate(
                ctx, gs.prop_out, state["dist"], "min", edge_transform=add_w
            )
            info = jnp.stack([rounds, iters]).astype(jnp.int32)
            return {"dist": dist, "info": info}, True

        state0 = {"dist": dist0, "info": jnp.zeros((pg.num_workers, 2), jnp.int32)}
        res = runtime.run_supersteps(pg, step, state0, max_steps=1,
                                     backend=backend, mesh=mesh, mode=mode,
                                     chunk_size=chunk_size)
    elif variant == "basic":

        def step(ctx, gs, state, step_idx):
            dist, active = state["dist"], state["active"]
            raw = gs.raw_out
            send_val = dist[raw.src_local] + raw.w
            valid = raw.mask & active[raw.src_local]
            inc, got, overflow = msg.combined_send(
                ctx, raw.dst_global, valid, send_val, "min", capacity=ctx.n_loc
            )
            new = jnp.where(gs.v_mask, jnp.minimum(dist, inc), dist)
            new_active = new < dist
            return (
                {"dist": new, "active": new_active},
                ~jnp.any(new_active),
                overflow,
            )

        state0 = {"dist": dist0, "active": ids == src_new}
        res = runtime.run_supersteps(pg, step, state0, max_steps=max_steps,
                                     backend=backend, mesh=mesh, mode=mode,
                                     chunk_size=chunk_size)
    else:
        raise ValueError(variant)
    return pg.to_global(res.state["dist"]), res
