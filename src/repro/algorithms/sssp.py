"""Single-source shortest paths (weighted Bellman-Ford flavor) — the
weighted generalization of the paper's §IV-C3 propagation channel
(`edge_transform = dist + w`), beyond the paper's min-label tables.

Variants:
  - "basic": per-superstep CombinedMessage from active (improved) vertices.
  - "prop":  Propagation channel with edge_transform = dist + w — the
             channel generalizes beyond min-label propagation.

``program(variant=..., source=...)`` builds the declarative
:class:`~repro.pregel.program.VertexProgram` — the source vertex (old-id)
is the problem input, resolved per graph inside ``init``; ``run`` is the
thin one-shot wrapper over :class:`repro.pregel.engine.Engine`.

The source is also the program's *query axis* (``query_init``):
``Engine.run_batch(prog, pg, sources)`` computes landmark distances —
one distance array per source — in a single compiled batched loop.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import message as msg
from repro.core import propagation as prop
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import engine
from repro.pregel.program import VertexProgram

INF = jnp.float32(np.inf)

VARIANTS = ("basic", "prop")


def _check_nonnegative_weights(pg) -> None:
    """Bellman-Ford with monotone-min halting is only correct on
    non-negative weights — a negative edge would need re-activation past
    the halt vote and silently yields wrong distances. Reject it loudly
    at init time instead (pad entries in the plans are zeros, so any
    negative entry is a real edge weight)."""
    ws = []
    if pg.raw_out is not None and pg.raw_out.w is not None:
        ws.append(pg.raw_out.w)
    if pg.prop_out is not None:
        if pg.prop_out.int_w is not None:
            ws.append(pg.prop_out.int_w)
        if pg.prop_out.cut.edge_w is not None:
            ws.append(pg.prop_out.cut.edge_w)
    for w in ws:
        if bool(jnp.any(w < 0)):
            raise ValueError(
                f"sssp requires non-negative edge weights; graph "
                f"{pg.name!r} has min weight {float(jnp.min(w))}")


def program(variant: str = "basic", *, source: int = 0,
            max_steps: int = 10_000) -> VertexProgram:
    """SSSP as a VertexProgram. Output: (n,) float32 distances in old-id
    space (inf = unreachable)."""
    if variant not in VARIANTS:
        raise ValueError(variant)

    def dist0_of(pg, src_old):
        src_new = int(pg.new_of_old.arr[src_old])
        ids = pg.global_ids()
        return jnp.where(ids == src_new, 0.0, INF).astype(jnp.float32), src_new

    def extract(pg, state):
        return pg.to_global(state["dist"])

    if variant == "prop":
        add_w = lambda v, w: v + (w[:, None] if v.ndim == 2 else w)

        def query_init(pg, src_old):
            _check_nonnegative_weights(pg)
            dist0, _ = dist0_of(pg, src_old)
            return {"dist": dist0,
                    "info": jnp.zeros((pg.num_workers, 2), jnp.int32)}

        def init(pg):
            return query_init(pg, source)

        def step(ctx, gs, state, step_idx):
            dist, rounds, iters = prop.propagate(
                ctx, gs.prop_out, state["dist"], "min", edge_transform=add_w
            )
            info = jnp.stack([rounds, iters]).astype(jnp.int32)
            return {"dist": dist, "info": info}, True

        return VertexProgram(
            name="sssp:prop", init=init, step=step, extract=extract,
            query_init=query_init, max_steps=1,
            meta={"algorithm": "sssp", "variant": variant, "source": source},
        )

    def query_init(pg, src_old):
        _check_nonnegative_weights(pg)
        dist0, src_new = dist0_of(pg, src_old)
        return {"dist": dist0, "active": pg.global_ids() == src_new}

    def init(pg):
        return query_init(pg, source)

    def step(ctx, gs, state, step_idx):
        dist, active = state["dist"], state["active"]
        raw = gs.raw_out
        send_val = dist[raw.src_local] + raw.w
        valid = raw.mask & active[raw.src_local]
        inc, got, overflow = msg.combined_send(
            ctx, raw.dst_global, valid, send_val, "min",
            capacity=ctx.edge_capacity(ctx.n_loc),
        )
        new = jnp.where(gs.v_mask, jnp.minimum(dist, inc), dist)
        new_active = new < dist
        return (
            {"dist": new, "active": new_active},
            ~jnp.any(new_active),
            overflow,
        )

    return VertexProgram(
        name="sssp:basic", init=init, step=step, extract=extract,
        query_init=query_init, max_steps=max_steps,
        meta={"algorithm": "sssp", "variant": variant, "source": source},
    )


def run(pg: PartitionedGraph, source_old: int, variant: str = "basic",
        max_steps: int = 10_000, backend: str = "vmap", mesh=None,
        mode=None, chunk_size: int = 64):
    prog = program(variant=variant, source=source_old, max_steps=max_steps)
    res = engine.run_program(prog, pg, backend=backend, mesh=mesh, mode=mode,
                             chunk_size=chunk_size)
    return res.output, res
