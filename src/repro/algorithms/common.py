"""Shared building blocks for the vertex-centric algorithms.

Includes the *baseline* (unoptimized, Pregel-style) implementations of the
patterns the optimized channels replace — these are what the paper's
Tables IV–VII compare against:

  - ``direct_request_respond``: 2-phase request/respond with DirectMessage
    (ids on both wires, no dedup) — what Pregel does without the
    request-respond channel;
  - ``pj_converge``: pointer-jumping loop to convergence (used inside
    Boruvka), with channel-selectable RR implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import message as msg
from repro.core import request_respond as rr
from repro.core import routing
from repro.core.channel import TRAFFIC_DTYPE, ChannelContext


def direct_request_respond(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    respond_vals: jax.Array,
    *,
    name: str = "basic_reqresp",
    wire_width: int = None,
    tags=None,
):
    """Baseline request-respond: requests via DirectMessage, responder
    replies per-request via DirectMessage (ids on both wires, no dedup).

    dst: (R,) requested global ids. If R == n_loc, request i is made by
    local vertex i (the reply routes back by vertex id). Otherwise pass
    `tags` (unique per worker, < R) so replies can be matched to requests
    (e.g. one request per edge) — the tag rides both wires, as it would in
    a real Pregel program.
    respond_vals: (n_loc,[D]) attribute exposed by every vertex.
    Returns (resp (R,[D]), overflow).
    """
    n_loc, w = ctx.n_loc, ctx.num_workers
    squeeze = respond_vals.ndim == 1
    rv = respond_vals[:, None] if squeeze else respond_vals
    d = rv.shape[-1]
    me = ctx.me()
    r = dst.shape[0]
    if tags is None:
        assert r == n_loc, "pass tags for non-per-vertex requests"
        tags = jnp.arange(n_loc, dtype=jnp.int32)
        requester = me * n_loc + tags
        tagged = False
    else:
        requester = jnp.broadcast_to(me * n_loc, (r,)).astype(jnp.int32)
        # reply is routed to any of our vertices; the tag does the matching
        tagged = True

    # phase 1: requests carry the requester id (+ tag) — no dedup.
    payload = {"requester": requester}
    if tagged:
        payload["tag"] = jnp.asarray(tags, jnp.int32)
    deliv = msg.direct_send(
        ctx, dst, valid, payload, capacity=r,
        name=name + "/request", wire_width=wire_width,
    )
    # phase 2: respond to each request individually.
    tgt_vals = jnp.concatenate([rv, jnp.zeros((1, d), rv.dtype)], 0)[
        jnp.clip(deliv.dst_local, 0, n_loc)
    ]  # (W*C, D) value of the requested vertex
    back_payload = {"v": tgt_vals}
    if tagged:
        back_payload["tag"] = deliv.payload["tag"]
    back = msg.direct_send(
        ctx,
        deliv.payload["requester"],
        deliv.mask,
        back_payload,
        capacity=r,
        name=name + "/respond",
        wire_width=wire_width,
    )
    slot = back.payload["tag"] if tagged else back.dst_local
    out = jnp.zeros((r + 1, d), rv.dtype)
    out = out.at[jnp.where(back.mask, slot, r)].set(
        jnp.where(back.mask[:, None], back.payload["v"], 0), mode="drop"
    )[:r]
    overflow = deliv.overflow | back.overflow
    return (out[:, 0] if squeeze else out), overflow


def cm_propagate(
    ctx: ChannelContext,
    raw_edges,
    init,
    combiner_name: str,
    *,
    active0,
    update=None,
    max_iters: int = 100_000,
    name: str = "basic_propagation",
):
    """Baseline label propagation: one CombinedMessage superstep per
    iteration until global convergence (what the Propagation channel
    replaces). O(diameter) global iterations. Returns (labels, iters)."""
    from repro.core import combiners as cb

    comb = cb.get(combiner_name)
    n_loc, w = ctx.n_loc, ctx.num_workers
    upd = update or (lambda lab, inc, got: comb.fn(lab, inc))

    def body(carry):
        lab, active, _, it, nb, nm = carry
        tmp = ChannelContext(ctx.axis, w, n_loc)
        tmp.route_cap = ctx.route_cap
        valid = raw_edges.mask & active[raw_edges.src_local]
        vals = lab[raw_edges.src_local]
        if raw_edges.w is not None:
            pass  # weighted variants pass transform via update
        inc, got, _ = msg.combined_send(
            tmp, raw_edges.dst_global, valid, vals, comb,
            capacity=tmp.edge_capacity(n_loc), name="x",
        )
        new = upd(lab, inc, got)
        new_active = jnp.any(
            (new != lab).reshape(n_loc, -1), axis=-1
        )
        changed = jax.lax.psum(jnp.any(new_active).astype(jnp.int32), ctx.axis) > 0
        db = sum(jax.tree_util.tree_leaves(tmp.stats_bytes))
        dm = sum(jax.tree_util.tree_leaves(tmp.stats_msgs))
        return new, new_active, changed, it + 1, nb + db, nm + dm

    def cond(carry):
        _, _, changed, it, _, _ = carry
        return changed & (it < max_iters)

    z = jnp.asarray(0, TRAFFIC_DTYPE)
    init_c = (init, active0, jnp.asarray(True), jnp.asarray(0, jnp.int32), z, z)
    lab, _, _, iters, nb, nm = jax.lax.while_loop(cond, body, init_c)
    ctx.add_traffic(name, nb, nm)
    return lab, iters


def jump_component():
    """:func:`pj_converge` (request-respond flavor) as a composition-stack
    component — the full-jumping stage shared by the composed S-V and the
    typed-channel Boruvka (args ``(parents, mask)``, single stat key)."""
    from repro.core import compose

    def fn(ctx, name, parents, mask):
        return pj_converge(ctx, parents, mask, use_reqresp=True, name=name)

    return compose.Component(fn)


def pj_converge(ctx: ChannelContext, parents, mask, *, use_reqresp=True,
                max_iters: int = 64, name: str = "pj_loop",
                wire_width: int = None):
    """Pointer-jump `parents` to fixpoint (all point to their root).

    Runs inside a while_loop; traffic is accumulated into the carry and
    then credited to `ctx`. Returns (roots, iters).
    """
    n_loc, w = ctx.n_loc, ctx.num_workers
    me = ctx.me()

    def body(carry):
        p, _, it, nb, nm = carry
        tmp = ChannelContext(ctx.axis, w, n_loc)
        if use_reqresp:
            grand, _ = rr.request(ctx=tmp, dst=p, valid=mask,
                                  respond_vals=p, capacity=n_loc, name="x")
        else:
            grand, _ = direct_request_respond(tmp, p, mask, p, name="x",
                                              wire_width=wire_width)
        newp = jnp.where(mask, grand, p)
        changed = jax.lax.psum(jnp.any(newp != p).astype(jnp.int32), ctx.axis) > 0
        db = sum(jax.tree_util.tree_leaves(tmp.stats_bytes))
        dm = sum(jax.tree_util.tree_leaves(tmp.stats_msgs))
        return newp, changed, it + 1, nb + db, nm + dm

    def cond(carry):
        _, changed, it, _, _ = carry
        return changed & (it < max_iters)

    init = (parents, jnp.asarray(True), jnp.asarray(0, jnp.int32),
            jnp.asarray(0, TRAFFIC_DTYPE), jnp.asarray(0, TRAFFIC_DTYPE))
    p, _, iters, nb, nm = jax.lax.while_loop(cond, body, init)
    ctx.add_traffic(name, nb, nm)
    return p, iters
