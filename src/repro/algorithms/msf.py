"""Minimum Spanning Forest — distributed Boruvka (Chung & Condon style,
the paper's MSF with heterogeneous message types; Table IV).

Per round: every component finds its minimum-weight outgoing edge
(RequestRespond for neighbor components + CombinedMessage with a
min-by-weight combiner carrying a 4-tuple), hooks, breaks 2-cycles,
pointer-jumps to the new roots, and relabels.

Variants:
  - "channels":   typed channels — RR requests are 4-byte ids, replies are
                  4-byte labels, only the candidate messages are 4-tuples.
                  Built as a :class:`repro.core.compose.Stacked`
                  composition (paper §V): the five constituent channels
                  are namespaced under ``msf/`` with per-component traffic
                  attribution, and the composed VertexProgram declares the
                  stack's registry entry set (no dry trace).
  - "monolithic": Pregel-style single message type — every message padded
                  to the largest (the 16-byte 4-tuple), no request dedup.

Weights must be unique (the generators use iid uniforms) — standard
Boruvka assumption; ids must fit float32 exactly (n < 2**24).

``program(variant=...)`` builds the declarative
:class:`~repro.pregel.program.VertexProgram`; ``run`` is the thin
one-shot wrapper over :class:`repro.pregel.engine.Engine`.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.algorithms import common
from repro.core import compose
from repro.core import message as msg
from repro.graph.pgraph import PartitionedGraph
from repro.pregel import engine
from repro.pregel.program import VertexProgram

TUPLE_W = 16  # bytes of the largest message (w, comp, src, dst)

VARIANTS = ("channels", "monolithic")


def typed_channels() -> compose.Stacked:
    """The typed-channel Boruvka as one composed stack: three
    request-respond lookups, the min-by-weight candidate combiner, and
    the pointer-jumping fixpoint, namespaced under ``msf/``."""
    return compose.stacked(
        "msf",
        nbrcomp=compose.request_component(),
        candidate=compose.combined_component("min_by_first"),
        cycle=compose.request_component(),
        relabel=compose.request_component(),
        jump=common.jump_component(),
    )


def program(variant: str = "channels", *, max_steps: int = 64) -> VertexProgram:
    """Boruvka MSF as a VertexProgram. Output: dict with the total forest
    ``weight``, its ``edges`` count, and per-vertex component ``labels``."""
    if variant not in VARIANTS:
        raise ValueError(variant)
    typed = variant == "channels"
    pad = None if typed else TUPLE_W
    chan = typed_channels() if typed else None

    def ask(ctx, gs, dst, valid, vals, name):
        if typed:
            return chan.call(ctx, name, dst, valid, vals, capacity=ctx.n_loc)
        return common.direct_request_respond(ctx, dst, valid, vals,
                                             name=name, wire_width=pad)

    def step(ctx, gs, state, step_idx):
        lab = state["L"]
        raw = gs.raw_out
        n_loc = ctx.n_loc
        gid = ctx.me() * n_loc + jnp.arange(n_loc, dtype=jnp.int32)

        # 1. neighbor component per edge (RR over edge destinations).
        #    Typed mode dedups per worker; monolithic mode cannot (per-edge
        #    requests would explode) so it asks once per vertex via a dense
        #    DirectMessage emulation — still id+pad on both wires.
        if typed:
            nbr_comp, ovf1 = chan.call(
                ctx, "nbrcomp", raw.dst_global, raw.mask, lab,
                capacity=n_loc,
            )
        else:
            # plain Pregel sends one request per edge (no worker dedup);
            # the edge slot rides along as the reply-matching tag.
            nbr_comp, ovf1 = common.direct_request_respond(
                ctx, raw.dst_global, raw.mask, lab, name="nbrcomp",
                wire_width=pad,
                tags=jnp.arange(raw.e_cap, dtype=jnp.int32),
            )
        src_comp = lab[raw.src_local]
        cross = raw.mask & (src_comp != nbr_comp)

        # 2. min-weight outgoing edge per component (min-by-first 4-tuple)
        cand = jnp.stack(
            [
                raw.w,
                nbr_comp.astype(jnp.float32),
                (ctx.me() * n_loc + raw.src_local).astype(jnp.float32),
                raw.dst_global.astype(jnp.float32),
            ],
            axis=-1,
        )
        if typed:
            minv, got, ovf2 = chan.call(ctx, "candidate", src_comp, cross,
                                        cand, capacity=n_loc)
        else:
            minv, got, ovf2 = msg.combined_send(
                ctx, src_comp, cross, cand, "min_by_first", capacity=n_loc,
                name="candidate", wire_width=pad,
            )

        # 3. hook roots to the chosen neighbor component
        hook_to = minv[:, 1].astype(jnp.int32)
        d = jnp.where(got, hook_to, gid)

        # 4. break 2-cycles (unique weights => both sides chose the same
        #    edge): the smaller id becomes the root and counts the edge.
        grand, ovf3 = ask(ctx, gs, d, gs.v_mask, d, "cycle")
        two_cycle = got & (grand == gid)
        d = jnp.where(two_cycle & (gid < hook_to), gid, d)
        count_edge = got & (~two_cycle | (gid < hook_to))
        add_w = jnp.where(count_edge, minv[:, 0], 0.0).sum()
        add_c = count_edge.sum().astype(jnp.int32)

        # 5. pointer-jump to convergence, then relabel via the new roots
        if typed:
            roots, pj_iters = chan.call(ctx, "jump", d, gs.v_mask)
        else:
            roots, pj_iters = common.pj_converge(
                ctx, d, gs.v_mask, use_reqresp=False, wire_width=pad
            )
        new_lab, ovf4 = ask(ctx, gs, lab, gs.v_mask, roots, "relabel")
        new_lab = jnp.where(gs.v_mask, new_lab, gid)

        any_got = jnp.any(got)
        halt = ~any_got
        overflow = ovf1 | ovf2 | ovf3 | ovf4
        return {
            "L": new_lab,
            "msf_w": state["msf_w"] + add_w,
            "msf_cnt": state["msf_cnt"] + add_c,
        }, halt, overflow

    def init(pg):
        assert pg.n < (1 << 24), "ids must be exact in float32"
        return {
            "L": pg.global_ids().astype(jnp.int32),
            "msf_w": jnp.zeros((pg.num_workers,), jnp.float32),
            "msf_cnt": jnp.zeros((pg.num_workers,), jnp.int32),
        }

    def extract(pg, state):
        total_w = float(np.asarray(state["msf_w"]).sum())
        total_c = int(np.asarray(state["msf_cnt"]).sum())
        return {"weight": total_w, "edges": total_c,
                "labels": pg.to_global(state["L"])}

    return VertexProgram(
        name=f"msf:{variant}", init=init, step=step, extract=extract,
        channels=chan, max_steps=max_steps,
        meta={"algorithm": "msf", "variant": variant},
    )


def run(pg: PartitionedGraph, variant: str = "channels", max_steps: int = 64,
        backend: str = "vmap", mesh=None, mode=None, chunk_size: int = 64):
    prog = program(variant=variant, max_steps=max_steps)
    res = engine.run_program(prog, pg, backend=backend, mesh=mesh, mode=mode,
                             chunk_size=chunk_size)
    return res.output, res
