"""``python -m repro`` — the registry-driven CLI.

Subcommands:

  list    every registered program (``algorithm:variant``), its declared
          channels and the graph plans it needs.
  run     run one program on a generated problem instance, verify it
          against the host oracle, and print the RunResult summary.
          ``--repeat N`` reuses the Engine session, so repeats report
          compile-cache hits instead of paying the trace again.
  bench   run a set of programs through one compile-once Engine per mode
          and print paper-style rows (supersteps / messages / bytes /
          wall time), optionally writing JSON.
  bench-batch
          the batched query plane: run every query-parametric program
          (or ``--keys``) over Q queries, once through one batched
          ``Engine.run_batch`` loop and once as a serial per-query loop,
          verify per-query outputs are bit-identical, and print
          queries/sec for both plus the speedup.
  serve   the continuous-batching query service: stream Q queries of one
          program through ``Engine.serve`` under a seeded Poisson
          arrival schedule, verify every served output bit-identical to
          a solo run, and print sustained queries/sec plus p50/p99
          latency. ``--smoke`` is the <60s CI configuration.
  plan    the channel planner: fingerprint each program on its problem
          graph, lower the declared channels to a concrete Plan, and
          print the per-knob decision table (``--explain``) with the
          predicted vs measured cost of every candidate.

Examples:

  python -m repro list
  python -m repro run wcc --scale 9
  python -m repro run sv:composed --scale 10 --mode fused --repeat 2
  python -m repro run wcc --scale 10 --plan auto
  python -m repro bench --scale 10 --keys wcc:basic,wcc:switch --json out.json
  python -m repro bench-batch --scale 10 --queries 16
  python -m repro serve reach:basic --scale 10 --queries 32 --lanes 8
  python -m repro serve --smoke
  python -m repro plan --explain
  python -m repro plan sssp:basic --scale 11 --queries 16 --explain
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.algorithms import (ALGORITHMS, BATCHED, DEFAULT_VARIANT, REGISTRY,
                              resolve)
from repro.graph import partition as partition_lib
from repro.graph import pgraph
from repro.pregel.engine import Engine


def _fmt_bytes(b: int) -> str:
    return f"{b / 1e6:.3f} MB" if b >= 1e6 else f"{b} B"


def _summary(res) -> str:
    cache = "hit" if res.cache_hit else f"compile {res.compile_time_s:.2f}s"
    return (f"steps {res.steps:5d}  msgs {res.total_msgs:10d}  "
            f"traffic {_fmt_bytes(res.total_bytes):>12s}  "
            f"wall {res.wall_time_s:7.3f}s  mode {res.mode}  "
            f"dispatches {res.dispatches}  [{cache}]")


def _knob_line(plan) -> str:
    """The resolved knob set a run actually compiled under."""
    return (f"knobs: mode={plan.mode} chunk={plan.chunk_size} "
            f"use_kernel={plan.use_kernel} route_impl={plan.route_impl} "
            f"route_batch={plan.route_batch} "
            f"dense_threshold={plan.dense_threshold} [plan: {plan.source}]")


def _prepare(spec, args):
    graph = spec.make_graph(args.scale, args.seed)
    thr = getattr(args, "mirror_threshold", None)
    if thr is not None and thr != "auto":
        thr = int(thr)
    pg = pgraph.partition_graph(graph, args.workers, args.partitioner,
                                build=spec.build, mirror_threshold=thr)
    # --max-steps is a per-run Engine override (prop/pagerank factories
    # manage their own budgets), not a factory knob
    inputs = spec.inputs(graph, args.seed)
    return graph, pg, inputs, spec.make(graph, args.seed)


def cmd_list(args) -> int:
    if args.json:
        out = {
            k: {
                "algorithm": s.algorithm,
                "variant": s.variant,
                "default": DEFAULT_VARIANT[s.algorithm] == s.variant,
                "build": list(s.build),
                "channel_class": s.channel_class,
                "channels": list(s.make(s.make_graph(6, 0)).channel_names()),
            }
            for k, s in sorted(REGISTRY.items())
        }
        print(json.dumps(out, indent=2))
        return 0
    print(f"{len(REGISTRY)} registered programs "
          f"({len(ALGORITHMS)} algorithms):\n")
    for algo in ALGORITHMS:
        for key, spec in sorted(REGISTRY.items()):
            if spec.algorithm != algo:
                continue
            star = "*" if DEFAULT_VARIANT[algo] == spec.variant else " "
            plans = ",".join(spec.build) or "-"
            print(f"  {star} {key:22s} [{spec.channel_class:6s}] "
                  f"plans: {plans}")
    print("\n(* = default variant for `python -m repro run <algorithm>`)")
    return 0


def cmd_run(args) -> int:
    spec = resolve(args.program)
    mode = args.mode
    if mode is None and (args.checkpoint_every or args.resume):
        mode = "chunked"    # checkpointing snapshots the chunked carry
    if mode is None:
        mode = None if args.plan == "auto" else "fused"
    shown_mode = mode or "auto"
    print(f"== {spec.key} (scale {args.scale}, W={args.workers}, "
          f"{args.partitioner} partition, mode {shown_mode}) ==")
    graph, pg, inputs, prog = _prepare(spec, args)
    print(f"graph: n={graph.n} edges={graph.num_edges}  program: {prog}")
    eng = Engine(mode=mode, chunk_size=args.chunk_size, plan=args.plan,
                 on_overflow=args.on_overflow)
    resume = args.resume
    if resume:
        import os
        if os.path.isdir(resume):
            from repro.pregel import checkpoint as ckpt_io
            resume = ckpt_io.latest(resume)
        if resume is None or not os.path.exists(resume):
            print(f"run: no checkpoint at {args.resume}")
            return 2
        print(f"resuming from {resume}")
    res = None
    for i in range(max(1, args.repeat)):
        res = eng.run(prog, pg, max_steps=args.max_steps,
                      checkpoint_every=args.checkpoint_every,
                      checkpoint_dir=args.checkpoint_dir,
                      resume=resume)
        if i == 0:
            print(_knob_line(res.plan))
        print(f"run {i}: {_summary(res)}")
        if res.resumed_from:
            print(f"  resumed at superstep {res.resumed_from}")
        if res.recovery:
            for ev in res.recovery:
                print(f"  recovered: overflow of {list(ev['channels'])} at "
                      f"superstep {ev['superstep']} -> cap_scales "
                      f"{ev['cap_scales']}")
    if args.repeat > 1:
        print(f"engine session: {eng.stats()}")
    for name in sorted(res.bytes_by_channel):
        print(f"  {name:32s} {res.bytes_by_channel[name]:12d} B "
              f"{res.msgs_by_channel[name]:10d} msgs")
    if args.check and spec.check is not None:
        spec.check(graph, pg, res, inputs)
        print("oracle: ok")
    return 0


def cmd_bench(args) -> int:
    keys = (args.keys.split(",") if args.keys
            else [f"{a}:{DEFAULT_VARIANT[a]}" for a in ALGORITHMS])
    modes = args.modes.split(",")
    engines = {m: Engine(mode=m, chunk_size=args.chunk_size,
                         plan=args.plan) for m in modes}
    rows = []
    shown = set()
    print(f"== bench (scale {args.scale}, W={args.workers}) ==")
    for name in keys:
        spec = resolve(name)
        graph, pg, inputs, prog = _prepare(spec, args)
        for mode in modes:
            res = engines[mode].run(prog, pg, max_steps=args.max_steps)
            if res.plan.key() not in shown:
                shown.add(res.plan.key())
                print(f"  {_knob_line(res.plan)}")
            rows.append({
                "program": spec.key, "mode": mode, "supersteps": res.steps,
                "messages": res.total_msgs, "bytes": res.total_bytes,
                "wall_time_s": round(res.wall_time_s, 4),
                "compile_time_s": round(res.compile_time_s, 4),
                "cache_hit": res.cache_hit,
                "plan": res.plan.to_json(),
            })
            print(f"  {spec.key:22s} [{mode:7s}] {_summary(res)}")
    stats = {m: engines[m].stats() for m in modes}
    print(f"engine sessions: {stats}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"scale": args.scale, "workers": args.workers,
                       "rows": rows, "engines": stats}, f, indent=2)
        print(f"wrote {args.json}")
    return 0


def cmd_bench_batch(args) -> int:
    import numpy as np

    named = args.programs or args.keys
    keys = named.split(",") if named else list(BATCHED)
    q = args.queries
    print(f"== bench-batch (scale {args.scale}, W={args.workers}, Q={q}, "
          f"mode {args.mode}) ==")
    rows = []
    for name in keys:
        spec = resolve(name)
        if spec.make_queries is None:
            print(f"  {spec.key:22s} (no query axis — skipped)")
            continue
        if args.channel_class != "all" \
                and spec.channel_class != args.channel_class:
            continue
        graph, pg, inputs, prog = _prepare(spec, args)
        queries = spec.queries(graph, args.seed, q)
        eng = Engine(mode=args.mode, chunk_size=args.chunk_size,
                     route_batch=args.route_batch)
        batched = lambda: eng.run_batch(prog, pg, queries,
                                        max_steps=args.max_steps)
        one = lambda s: eng.run_batch(prog, pg, [s],
                                      max_steps=args.max_steps)
        # warm both executables, then verify the batch against the
        # serial loop query by query before timing anything
        res_b = batched()
        serial = [one(s) for s in queries]
        for qi in range(len(queries)):
            np.testing.assert_array_equal(
                np.asarray(res_b.outputs[qi]),
                np.asarray(serial[qi].outputs[0]))
        t0 = time.perf_counter()
        batched()
        t_batched = time.perf_counter() - t0
        t0 = time.perf_counter()
        for s in queries:
            one(s)
        t_serial = time.perf_counter() - t0
        row = {"program": spec.key, "q": len(queries),
               "channel_class": spec.channel_class,
               "route_batch": eng.route_batch,
               "supersteps": res_b.steps,
               "queries_per_s_serial": len(queries) / t_serial,
               "queries_per_s_batched": len(queries) / t_batched,
               "speedup": t_serial / t_batched,
               "bytes": res_b.total_bytes}
        rows.append(row)
        print(f"  {spec.key:22s} [{spec.channel_class:6s}] "
              f"steps {res_b.steps:4d}  "
              f"serial {row['queries_per_s_serial']:8.1f} q/s  "
              f"batched {row['queries_per_s_batched']:8.1f} q/s  "
              f"speedup {row['speedup']:6.2f}x  [outputs bit-identical]")
    # speedup by channel class: static-plan channels batch through the
    # query vmap alone; routed channels additionally share the
    # union-frontier route pass (route_batch="union")
    by_class = {}
    for row in rows:
        by_class.setdefault(row["channel_class"], []).append(row["speedup"])
    for cls in sorted(by_class):
        sp = by_class[cls]
        geo = float(np.exp(np.mean(np.log(sp))))
        print(f"  -- {cls:6s} ({len(sp)} programs): "
              f"geomean speedup {geo:6.2f}x  "
              f"(min {min(sp):.2f}x, max {max(sp):.2f}x)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"scale": args.scale, "workers": args.workers,
                       "q": q, "mode": args.mode,
                       "route_batch": args.route_batch or "union",
                       "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")
    return 0


def cmd_serve(args) -> int:
    import numpy as np

    from repro.pregel.serve import QueryQueue

    if args.smoke:
        # the <60s CI stage: small scale, forced refills, full
        # bit-identity verification
        args.program = args.program or "reach:basic"
        args.scale = 8
        args.workers = 4
        args.queries = 12
        args.lanes = 3
        args.chunk_size = 3
    if args.program is None:
        print("serve: a program key is required (or use --smoke)")
        return 2
    spec = resolve(args.program)
    if spec.make_queries is None:
        print(f"serve: {spec.key} has no query axis")
        return 2
    chunk = args.serve_chunk if args.serve_chunk else (args.chunk_size or 64)
    print(f"== serve {spec.key} (scale {args.scale}, W={args.workers}, "
          f"Q={args.queries}, lanes={args.lanes}, chunk={chunk}, "
          f"rate={args.rate}/step) ==")
    graph, pg, inputs, prog = _prepare(spec, args)
    schedule = spec.stream(graph, args.seed, args.queries, args.rate)
    eng = Engine(mode="chunked", chunk_size=chunk,
                 route_batch=args.route_batch)
    res = eng.serve(prog, pg, QueryQueue.from_schedule(schedule),
                    num_lanes=args.lanes, max_steps=args.max_steps)
    lat = res.latency_summary()
    print(f"served {res.num_queries} queries through {res.num_lanes} lanes: "
          f"{res.dispatches} dispatches, {res.supersteps} supersteps "
          f"(clock {res.clock}), wall {res.wall_time_s:.3f}s "
          f"[{'hit' if res.cache_hit else f'compile {res.compile_time_s:.2f}s'}]")
    print(f"  sustained {res.queries_per_s:8.1f} q/s   latency p50 "
          f"{lat['p50_steps']:.0f} / p99 {lat['p99_steps']:.0f} steps "
          f"({lat['p50_wall_s'] * 1e3:.1f} / {lat['p99_wall_s'] * 1e3:.1f} ms)")
    if args.check:
        # every served answer must be bit-identical to a solo run of the
        # same query (Q=1 run_batch — itself pinned to Engine.run by the
        # tier-1 suite)
        for rec in res.records:
            solo = eng.run_batch(prog, pg, [rec.query],
                                 max_steps=args.max_steps)
            np.testing.assert_array_equal(np.asarray(rec.output),
                                          np.asarray(solo.outputs[0]))
            assert rec.steps == int(solo.query_steps[0]), \
                (rec.qid, rec.steps, int(solo.query_steps[0]))
            assert rec.bytes_by_channel == solo.query_bytes(0), rec.qid
            assert rec.msgs_by_channel == solo.query_msgs(0), rec.qid
        print(f"  bit-identity: all {res.num_queries} served outputs, step "
              "counts and traffic match solo runs")
    return 0


def cmd_plan(args) -> int:
    from repro.plan import Planner

    keys = (args.programs.split(",") if isinstance(args.programs, str)
            else args.programs) or ["wcc:switch", "sssp:basic"]
    planner = Planner(calibrate=not args.no_calibrate)
    print(f"== plan (scale {args.scale}, W={args.workers}, "
          f"Q={args.queries}) ==")
    for name in keys:
        spec = resolve(name)
        graph, pg, inputs, prog = _prepare(spec, args)
        plan = planner.plan(prog, pg, num_queries=args.queries)
        print(f"\n{spec.key}  (n={graph.n}, edges={graph.num_edges}, "
              f"class={spec.channel_class})")
        if args.explain:
            print(plan.explain())
        else:
            print(_knob_line(plan))
    if not args.no_calibrate:
        from repro.plan import cost_model
        print(f"\ncalibration cache: {cost_model.cache_dir()}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_list = sub.add_parser("list", help="list registered programs")
    p_list.add_argument("--json", action="store_true")
    p_list.set_defaults(fn=cmd_list)

    def common(p):
        p.add_argument("--scale", type=int, default=10,
                       help="graph scale (n = 2^scale)")
        p.add_argument("--workers", type=int, default=8)
        p.add_argument("--partitioner", default="random",
                       choices=sorted(partition_lib.PARTITIONERS))
        p.add_argument("--mirror-threshold", default=None,
                       help="hub-mirroring degree threshold for the "
                            "scatter/prop plans: an int, 'auto', or unset "
                            "(off). See docs/scaling.md.")
        p.add_argument("--chunk-size", type=int, default=None,
                       help="chunked-mode dispatch width (default 64; "
                            "None lets --plan auto choose)")
        p.add_argument("--max-steps", type=int, default=None)
        p.add_argument("--seed", type=int, default=0)

    p_run = sub.add_parser("run", help="run one program, verify the oracle")
    p_run.add_argument("program",
                       help="algorithm (default variant) or algorithm:variant")
    common(p_run)
    p_run.add_argument("--mode", default=None,
                       choices=("host", "fused", "chunked"),
                       help="execution mode (default: fused, or the "
                            "planner's choice under --plan auto)")
    p_run.add_argument("--plan", default="manual",
                       choices=("manual", "auto"),
                       help="knob source: manual = flags/env/defaults, "
                            "auto = the cost-model planner (explicit "
                            "flags still win)")
    p_run.add_argument("--repeat", type=int, default=1,
                       help="re-run through the same Engine session")
    p_run.add_argument("--no-check", dest="check", action="store_false",
                       help="skip the host-oracle verification")
    p_run.add_argument("--on-overflow", default="raise",
                       choices=("raise", "escalate"),
                       help="channel-capacity overflow policy: escalate "
                            "re-buckets the overflowed caps and replays")
    p_run.add_argument("--checkpoint-every", type=int, default=None,
                       help="snapshot the run every K supersteps "
                            "(chunked mode; needs --checkpoint-dir)")
    p_run.add_argument("--checkpoint-dir", default=None,
                       help="directory checkpoints are written into")
    p_run.add_argument("--resume", default=None,
                       help="checkpoint file (or directory: newest is "
                            "taken) to resume from — bit-identical to "
                            "the uninterrupted run")
    p_run.set_defaults(fn=cmd_run)

    p_bench = sub.add_parser("bench", help="bench programs via one Engine")
    p_bench.add_argument("--keys", default=None,
                         help="comma list of programs (default: one per "
                              "algorithm)")
    common(p_bench)
    p_bench.add_argument("--modes", default="fused",
                         help="comma list of execution modes")
    p_bench.add_argument("--plan", default="manual",
                         choices=("manual", "auto"),
                         help="knob source (auto = cost-model planner; "
                              "the per-engine --modes stay explicit)")
    p_bench.add_argument("--json", default=None, help="write rows to JSON")
    p_bench.set_defaults(fn=cmd_bench)

    p_bb = sub.add_parser(
        "bench-batch",
        help="batched query plane: run_batch vs a serial per-query loop")
    p_bb.add_argument("--keys", default=None,
                      help="comma list of batched programs "
                           "(default: every query-parametric program)")
    p_bb.add_argument("--programs", default=None,
                      help="alias for --keys (takes precedence)")
    common(p_bb)
    p_bb.add_argument("--mode", default="fused",
                      choices=("host", "fused", "chunked"))
    p_bb.add_argument("--channel-class", default="all",
                      choices=("static", "routed", "all"),
                      help="only bench programs of this data-plane family")
    p_bb.add_argument("--route-batch", default=None,
                      choices=("union", "lane"),
                      help="routed-channel batching strategy "
                           "(default: union, see REPRO_ROUTE_BATCH)")
    p_bb.add_argument("--queries", type=int, default=16,
                      help="batch size Q")
    p_bb.add_argument("--json", default=None, help="write rows to JSON")
    p_bb.set_defaults(fn=cmd_bench_batch)

    p_sv = sub.add_parser(
        "serve",
        help="continuous-batching query service under a Poisson workload")
    p_sv.add_argument("program", nargs="?", default=None,
                      help="a query-parametric program "
                           "(algorithm or algorithm:variant)")
    common(p_sv)
    p_sv.add_argument("--queries", type=int, default=32,
                      help="number of queries in the arrival stream")
    p_sv.add_argument("--lanes", type=int, default=8,
                      help="always-on query lanes (the batch width)")
    p_sv.add_argument("--serve-chunk", type=int, default=None,
                      help="supersteps per dispatch = admission "
                           "granularity (default: --chunk-size)")
    p_sv.add_argument("--rate", type=float, default=1.0,
                      help="Poisson arrival rate (queries per superstep)")
    p_sv.add_argument("--route-batch", default=None,
                      choices=("union", "lane"))
    p_sv.add_argument("--no-check", dest="check", action="store_false",
                      help="skip the per-query bit-identity verification")
    p_sv.add_argument("--smoke", action="store_true",
                      help="the <60s CI configuration (small scale, "
                           "forced refills, full verification)")
    p_sv.set_defaults(fn=cmd_serve)

    p_plan = sub.add_parser(
        "plan",
        help="lower programs' channels to concrete Plans (decision table)")
    p_plan.add_argument("programs", nargs="*", default=None,
                        help="programs to plan (default: wcc:switch, "
                             "sssp:basic)")
    common(p_plan)
    p_plan.add_argument("--queries", type=int, default=0,
                        help="plan for a Q-query batch (0 = single run)")
    p_plan.add_argument("--explain", action="store_true",
                        help="print the full per-knob decision table "
                             "(candidates, predicted vs measured cost)")
    p_plan.add_argument("--no-calibrate", action="store_true",
                        help="skip the timed calibration probes — corpus "
                             "fits and defaults only")
    p_plan.set_defaults(fn=cmd_plan)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
