"""Host-side (pure python/numpy) oracles for algorithm tests."""
from __future__ import annotations

import numpy as np

from repro.graph.generators import EdgeList


def pagerank_oracle(g: EdgeList, iters: int = 20, damping: float = 0.85):
    n = g.n
    out_deg = np.zeros(n, np.int64)
    np.add.at(out_deg, g.edges[:, 0], 1)
    pr = np.full(n, 1.0 / n)
    src, dst = g.edges[:, 0], g.edges[:, 1]
    for _ in range(iters):
        contrib = np.where(out_deg > 0, pr / np.maximum(out_deg, 1), 0.0)
        incoming = np.zeros(n)
        np.add.at(incoming, dst, contrib[src])
        sink = pr[out_deg == 0].sum()
        pr = (1 - damping) / n + damping * (incoming + sink / n)
    return pr


def personalized_pagerank_oracle(g: EdgeList, source: int, iters: int = 20,
                                 damping: float = 0.85):
    """Personalized PageRank: teleport and sink mass go to ``source``."""
    n = g.n
    out_deg = np.zeros(n, np.int64)
    np.add.at(out_deg, g.edges[:, 0], 1)
    e = np.zeros(n)
    e[source] = 1.0
    pr = e.copy()
    src, dst = g.edges[:, 0], g.edges[:, 1]
    for _ in range(iters):
        contrib = np.where(out_deg > 0, pr / np.maximum(out_deg, 1), 0.0)
        incoming = np.zeros(n)
        np.add.at(incoming, dst, contrib[src])
        sink = pr[out_deg == 0].sum()
        pr = (1 - damping) * e + damping * (incoming + sink * e)
    return pr


def sssp_oracle(g: EdgeList, source: int):
    """Bellman-Ford (weights default 1)."""
    n = g.n
    w = g.weights if g.weights is not None else np.ones(len(g.edges), np.float32)
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    src, dst = g.edges[:, 0], g.edges[:, 1]
    for _ in range(n):
        new = dist.copy()
        np.minimum.at(new, dst, dist[src] + w)
        if np.array_equal(
            new, dist, equal_nan=True
        ) or np.all((new == dist) | (np.isinf(new) & np.isinf(dist))):
            break
        dist = new
    return dist


def scc_oracle(g: EdgeList) -> np.ndarray:
    """Kosaraju SCC labels (min vertex id per SCC), iterative."""
    n = g.n
    adj = [[] for _ in range(n)]
    radj = [[] for _ in range(n)]
    for s, d in g.edges:
        adj[s].append(int(d))
        radj[d].append(int(s))
    visited = np.zeros(n, bool)
    order = []
    for s in range(n):
        if visited[s]:
            continue
        stack = [(s, 0)]
        visited[s] = True
        while stack:
            u, i = stack[-1]
            if i < len(adj[u]):
                stack[-1] = (u, i + 1)
                v = adj[u][i]
                if not visited[v]:
                    visited[v] = True
                    stack.append((v, 0))
            else:
                order.append(u)
                stack.pop()
    label = np.full(n, -1, np.int64)
    for s in reversed(order):
        if label[s] >= 0:
            continue
        comp = [s]
        label[s] = s
        while comp:
            u = comp.pop()
            for v in radj[u]:
                if label[v] < 0:
                    label[v] = s
                    comp.append(v)
    # canonicalize to min id per SCC
    mins = {}
    for v in range(n):
        mins[label[v]] = min(mins.get(label[v], n), v)
    return np.array([mins[label[v]] for v in range(n)], np.int64)


def msf_weight_oracle(g: EdgeList) -> float:
    """Total weight of the minimum spanning forest (Kruskal)."""
    assert g.weights is not None
    order = np.argsort(g.weights)
    parent = np.arange(g.n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    total = 0.0
    for i in order:
        s, d = g.edges[i]
        rs, rd = find(int(s)), find(int(d))
        if rs != rd:
            parent[rs] = rd
            total += float(g.weights[i])
    return total
