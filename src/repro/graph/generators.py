"""Synthetic graph generators (numpy, host-side data pipeline).

Mirrors the paper's dataset families (Table III): power-law web/social
graphs (R-MAT), chains, random rooted trees, road-network-like grids, and
weighted power-law graphs for MSF.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class EdgeList:
    """A host-side graph: n vertices, edges (E, 2) int64, optional weights."""

    n: int
    edges: np.ndarray  # (E, 2) int64 (src, dst)
    weights: Optional[np.ndarray] = None  # (E,) float32
    directed: bool = True
    name: str = "graph"

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    def symmetrized(self) -> "EdgeList":
        """Undirected view: both directions present, self-loops removed."""
        e = self.edges
        w = self.weights
        rev = e[:, ::-1]
        edges = np.concatenate([e, rev], axis=0)
        weights = None if w is None else np.concatenate([w, w], axis=0)
        return dedup(EdgeList(self.n, edges, weights, directed=False,
                              name=self.name + "+sym"))

    def reversed(self) -> "EdgeList":
        return EdgeList(self.n, self.edges[:, ::-1].copy(), self.weights,
                        self.directed, self.name + "+rev")


def dedup(g: EdgeList) -> EdgeList:
    """Remove duplicate edges and self-loops (keeping min weight)."""
    e = g.edges
    keep = e[:, 0] != e[:, 1]
    e = e[keep]
    w = None if g.weights is None else g.weights[keep]
    key = e[:, 0] * np.int64(g.n) + e[:, 1]
    order = np.argsort(key, kind="stable")
    key, e = key[order], e[order]
    first = np.ones(len(key), dtype=bool)
    first[1:] = key[1:] != key[:-1]
    if w is not None:
        w = np.minimum.reduceat(w[order], np.flatnonzero(first)) if len(key) else w
    return EdgeList(g.n, e[first], w, g.directed, g.name)


def rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    weighted: bool = False,
    directed: bool = True,
) -> EdgeList:
    """R-MAT power-law graph: n = 2**scale, E = n * edge_factor."""
    n = 1 << scale
    e = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(e, dtype=np.int64)
    dst = np.zeros(e, dtype=np.int64)
    for level in range(scale):
        r = rng.random(e)
        # quadrant probabilities (a, b, c, d)
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        src = (src << 1) | go_down.astype(np.int64)
        dst = (dst << 1) | go_right.astype(np.int64)
    edges = np.stack([src, dst], axis=1)
    w = rng.random(e).astype(np.float32) if weighted else None
    g = dedup(EdgeList(n, edges, w, directed, name=f"rmat{scale}"))
    return g


def chain(n: int, directed: bool = False) -> EdgeList:
    """Path graph 0-1-...-(n-1); the paper's worst case for propagation."""
    i = np.arange(n - 1, dtype=np.int64)
    edges = np.stack([i, i + 1], axis=1)
    g = EdgeList(n, edges, None, directed, name=f"chain{n}")
    return g if directed else g.symmetrized()


def parent_chain(n: int, seed: int = 0, shuffle: bool = True) -> np.ndarray:
    """Pointer-jumping input: parents forming one long chain (D[i] = i-1
    under a random relabeling). Returns parent array (n,)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n) if shuffle else np.arange(n)
    par = np.empty(n, dtype=np.int64)
    par[perm[0]] = perm[0]
    par[perm[1:]] = perm[:-1]
    return par


def random_tree_parents(n: int, seed: int = 0) -> np.ndarray:
    """Random recursive tree parents (vertex i attaches to U[0, i))."""
    rng = np.random.default_rng(seed)
    par = np.zeros(n, dtype=np.int64)
    if n > 1:
        par[1:] = (rng.random(n - 1) * np.arange(1, n)).astype(np.int64)
    perm = rng.permutation(n)
    out = np.empty(n, dtype=np.int64)
    out[perm] = perm[par]
    return out


def random_tree(n: int, seed: int = 0) -> EdgeList:
    """Random rooted tree as an edge list child->parent (directed)."""
    par = random_tree_parents(n, seed)
    v = np.arange(n, dtype=np.int64)
    keep = par != v
    edges = np.stack([v[keep], par[keep]], axis=1)
    return EdgeList(n, edges, None, True, name=f"tree{n}")


def grid2d(side: int, directed: bool = False) -> EdgeList:
    """side x side grid — road-network stand-in (large diameter, low degree)."""
    n = side * side
    idx = np.arange(n, dtype=np.int64).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    edges = np.concatenate([right, down], axis=0)
    g = EdgeList(n, edges, None, directed, name=f"grid{side}x{side}")
    return g if directed else g.symmetrized()


def uniform_random(n: int, e: int, seed: int = 0, weighted: bool = False,
                   directed: bool = True) -> EdgeList:
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, n, size=(e, 2), dtype=np.int64)
    w = rng.random(e).astype(np.float32) if weighted else None
    return dedup(EdgeList(n, edges, w, directed, name=f"rand{n}"))


def components_ground_truth(g: EdgeList) -> np.ndarray:
    """Connected-component labels via union-find (oracle for WCC/S-V tests)."""
    parent = np.arange(g.n, dtype=np.int64)

    def find(x):
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for s, d in g.edges:
        rs, rd = find(s), find(d)
        if rs != rd:
            parent[max(rs, rd)] = min(rs, rd)
    labels = np.array([find(x) for x in range(g.n)], dtype=np.int64)
    # canonical: min vertex id in component
    return labels
