"""PartitionedGraph — static-shape distributed graph with channel plans.

All routing decisions that the paper's system makes with per-message
hashing are precomputed here (host-side numpy) into dense, static-shape
plans. Arrays carry a leading ``W`` (worker) axis; the Pregel runtime maps
step functions over it with ``vmap`` (logical workers on one device) or
``shard_map`` (real mesh), and channels communicate via axis-name
collectives — identical code in both modes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import partition as partition_lib
from repro.graph.generators import EdgeList
from repro.pregel.errors import PlanRangeError

INT32_MAX = 2**31 - 1


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _check_int32_extent(what: str, value: int) -> None:
    """Plan tables and wire slots are int32; any extent past 2**31 - 1
    would silently wrap into another worker's range and corrupt routes.
    Validated at plan-build/trace time (extents are pure functions of the
    static caps) so the failure is structured, not a wrong answer."""
    if value > INT32_MAX:
        raise PlanRangeError(
            f"{what} = {value} exceeds the int32 range ({INT32_MAX}); "
            "the wire-slot ids (owner * C + rank) and plan tables would "
            "wrap. Reduce workers x capacity (or shrink the graph/caps).",
            channels=(what,),
        )


def _bucket_cap(x: int, align: int) -> int:
    """Slot caps are bucketed to the next power of two (floored at
    ``align``): every static cap enters the compiled loop's shape
    signature, so same-topology graphs whose raw per-worker counts differ
    slightly land on identical caps and share one Engine compile."""
    x = max(x, 1)
    return max(align, 1 << (x - 1).bit_length())


class HostArray:
    """Host-side numpy array kept OUT of the jax pytree (static aux data
    with identity hashing — it never changes after construction)."""

    def __init__(self, arr):
        self.arr = np.asarray(arr)

    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return other is self


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ScatterPlan:
    """Static routing plan for the scatter-combine pattern.

    Per worker: local edges sorted by destination, sender-side dedup to one
    entry per unique destination, positional slots into the all_to_all
    buffer (no vertex ids on the wire), and the receive-side local indices.
    """

    edge_src: jax.Array      # (W, E_cap) i32 local src idx (pad 0, masked by seg)
    edge_seg: jax.Array      # (W, E_cap) i32 unique-dst index (pad U_cap: dropped)
    edge_w: Optional[jax.Array]  # (W, E_cap) f32 edge weights or None
    pack_slot: jax.Array     # (W, U_cap) i32 slot in (W*C) send buf (pad W*C)
    recv_local: jax.Array    # (W, W, C) i32 local dst idx (pad n_loc)
    send_count: jax.Array    # (W, W) i32 real entries per peer
    # autotuned segment-combine kernel plan (host-built from the edge
    # distribution; the statics ride the treedef, so the block choice is
    # part of every compile-cache key that includes this plan)
    chunk_start: Optional[jax.Array]  # (W, NB) i32 first covering chunk
    chunk_count: Optional[jax.Array]  # (W, NB) i32 covering chunks per block
    # static metadata
    n_loc: int = dataclasses.field(metadata=dict(static=True))
    num_workers: int = dataclasses.field(metadata=dict(static=True))
    e_cap: int = dataclasses.field(metadata=dict(static=True))
    u_cap: int = dataclasses.field(metadata=dict(static=True))
    slot_cap: int = dataclasses.field(metadata=dict(static=True))
    remote_entries: int = dataclasses.field(metadata=dict(static=True))
    total_edges: int = dataclasses.field(metadata=dict(static=True))
    block_rows: int = dataclasses.field(default=0, metadata=dict(static=True))
    block_edges: int = dataclasses.field(default=0, metadata=dict(static=True))
    max_chunks: int = dataclasses.field(default=0, metadata=dict(static=True))
    # hub mirroring (partition_graph(mirror_threshold=...)): cut edges
    # whose source degree exceeds the threshold are *re-homed* to the
    # destination owner and combined there (mirror-side pre-combine). The
    # mirror reads the hub's value from an extended gather index
    # ``n_loc + owner(hub) * hub_cap + hub_rank`` — the per-superstep
    # mirror->master refresh is a static all_gather of each owner's
    # exported-hub table (see repro.core.scatter_combine).
    hub_local: Optional[jax.Array] = None  # (W, hub_cap) i32 owner-local
    #                                        idx of exported hubs (pad n_loc)
    hub_cap: int = dataclasses.field(default=0, metadata=dict(static=True))
    mirrored_edges: int = dataclasses.field(
        default=0, metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RawEdges:
    """Unsorted per-worker edge lists (src local) — what the *baseline*
    message channels iterate over each superstep (no preprocessing)."""

    src_local: jax.Array   # (W, E_cap) i32
    dst_global: jax.Array  # (W, E_cap) i32
    w: Optional[jax.Array]  # (W, E_cap) f32
    mask: jax.Array        # (W, E_cap) bool
    e_cap: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PropPlan:
    """Plan for the propagation channel: partition-internal CSR (for the
    local fixpoint) + a ScatterPlan over cut edges (for global exchange)."""

    int_src: jax.Array       # (W, Ei_cap) i32 local src idx
    int_dst: jax.Array       # (W, Ei_cap) i32 local dst idx, sorted (pad n_loc)
    int_w: Optional[jax.Array]   # (W, Ei_cap) f32
    cut: ScatterPlan
    ei_cap: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PartitionedGraph:
    v_mask: jax.Array        # (W, n_loc) bool
    deg_out: jax.Array       # (W, n_loc) i32
    scatter_out: Optional[ScatterPlan]
    scatter_in: Optional[ScatterPlan]
    prop_out: Optional[PropPlan]
    prop_in: Optional[PropPlan]
    raw_out: Optional[RawEdges]
    raw_in: Optional[RawEdges]
    n: int = dataclasses.field(metadata=dict(static=True))
    num_workers: int = dataclasses.field(metadata=dict(static=True))
    n_loc: int = dataclasses.field(metadata=dict(static=True))
    directed: bool = dataclasses.field(metadata=dict(static=True))
    name: str = dataclasses.field(metadata=dict(static=True))
    new_of_old: HostArray = dataclasses.field(metadata=dict(static=True))
    # partition-derived per-peer capacity bound for *edge-derived* routed
    # sends (max over (home worker, owner) pairs of unique destinations,
    # both orientations, pow2-bucketed; 0 = unknown). Deduping routed
    # channels can size their per-owner all_to_all buffers with this
    # instead of the full-width n_loc — see ChannelContext.edge_capacity.
    # A static field, so it rides the treedef into graph_signature and
    # every Engine compile-cache key.
    route_cap: int = dataclasses.field(default=0, metadata=dict(static=True))

    @property
    def n_pad(self) -> int:
        return self.num_workers * self.n_loc

    def to_local(self, per_vertex_np):
        """(n,) old-id host array -> (W, n_loc) device array in new-id space."""
        arr = np.asarray(per_vertex_np)
        out_shape = (self.n_pad,) + arr.shape[1:]
        out = np.zeros(out_shape, dtype=arr.dtype)
        out[self.new_of_old.arr] = arr
        return jnp.asarray(out.reshape((self.num_workers, self.n_loc) + arr.shape[1:]))

    def to_global(self, per_local):
        """(W, n_loc, ...) device array -> (n,) host array in old-id space."""
        flat = np.asarray(per_local).reshape((self.n_pad,) + per_local.shape[2:])
        return flat[self.new_of_old.arr]

    def global_ids(self):
        """(W, n_loc) the new-space global id of every slot."""
        return (
            jnp.arange(self.num_workers, dtype=jnp.int32)[:, None] * self.n_loc
            + jnp.arange(self.n_loc, dtype=jnp.int32)[None, :]
        )


def _build_scatter_plan(
    src_new: np.ndarray,
    dst_new: np.ndarray,
    weights: Optional[np.ndarray],
    n_workers: int,
    n_loc: int,
    align: int = 8,
    mirror_threshold: Optional[int] = None,
) -> ScatterPlan:
    W = n_workers
    n_pad = W * n_loc
    owner_src = src_new // n_loc
    owner_dst = dst_new // n_loc

    # hub mirroring: a cut edge whose source degree (in this plan's
    # orientation) exceeds the threshold is re-homed to the *destination*
    # owner — the mirror combines it locally, so the hub's fan-out costs
    # one broadcast slot per worker instead of one wire entry per unique
    # remote destination. src_idx below is the (possibly extended) gather
    # index each edge reads its source value from.
    home = owner_src
    src_idx = src_new - owner_src * n_loc
    hub_cap = 0
    mirrored = 0
    hub_local_np = None
    if mirror_threshold is not None and len(src_new):
        deg_src = np.bincount(src_new, minlength=n_pad)
        mir = (deg_src[src_new] > mirror_threshold) & (owner_src != owner_dst)
        if mir.any():
            hub_ids = np.unique(src_new[mir])  # sorted => grouped by owner
            hub_owner = hub_ids // n_loc
            per_owner = np.bincount(hub_owner, minlength=W)
            hub_cap = _bucket_cap(int(per_owner.max(initial=0)), align)
            starts = np.concatenate([[0], np.cumsum(per_owner)])[:-1]
            rank_of = np.zeros(n_pad, np.int64)
            rank_of[hub_ids] = np.arange(len(hub_ids)) - starts[hub_owner]
            hub_local_np = np.full((W, hub_cap), n_loc, np.int32)
            for w in range(W):
                mine = hub_ids[hub_owner == w]
                hub_local_np[w, : len(mine)] = (mine - w * n_loc).astype(
                    np.int32)
            home = np.where(mir, owner_dst, owner_src)
            src_idx = np.where(
                mir, n_loc + owner_src * hub_cap + rank_of[src_new], src_idx)
            mirrored = int(mir.sum())

    e_caps, u_caps, c_caps = [], [], []
    per_worker = []
    for w in range(W):
        sel = home == w
        s, d = src_idx[sel], dst_new[sel]
        wt = weights[sel] if weights is not None else None
        order = np.lexsort((s, d))
        s, d = s[order], d[order]
        wt = wt[order] if wt is not None else None
        u, seg = np.unique(d, return_inverse=True) if len(d) else (
            np.zeros(0, np.int64), np.zeros(0, np.int64))
        owners_u = u // n_loc
        cnt = np.bincount(owners_u, minlength=W)
        per_worker.append((s, d, wt, u, seg, owners_u, cnt))
        e_caps.append(len(s))
        u_caps.append(len(u))
        c_caps.append(cnt.max(initial=0))

    e_cap = _bucket_cap(max(e_caps), align)
    u_cap = _bucket_cap(max(u_caps), align)
    c = _bucket_cap(int(max(c_caps)), align)
    _check_int32_extent("scatter_plan/pack_slot (W * slot_cap)", W * c)
    _check_int32_extent(
        "scatter_plan/edge_src (n_loc + W * hub_cap)",
        n_loc + W * hub_cap)

    edge_src = np.zeros((W, e_cap), np.int32)
    edge_seg = np.full((W, e_cap), u_cap, np.int32)
    edge_w = np.zeros((W, e_cap), np.float32) if weights is not None else None
    pack_slot = np.full((W, u_cap), W * c, np.int32)
    recv_local = np.full((W, W, c), n_loc, np.int32)
    send_count = np.zeros((W, W), np.int32)
    remote = 0
    total = 0

    for w in range(W):
        s, d, wt, u, seg, owners_u, cnt = per_worker[w]
        k, e = len(u), len(s)
        total += e
        edge_src[w, :e] = s.astype(np.int32)
        edge_seg[w, :e] = seg.astype(np.int32)
        if edge_w is not None and e:
            edge_w[w, :e] = wt
        starts = np.concatenate([[0], np.cumsum(cnt)])[:-1]  # (W,)
        # u is sorted by global id => grouped by owner, contiguous
        rank = np.arange(k) - starts[owners_u]
        pack_slot[w, :k] = (owners_u * c + rank).astype(np.int32)
        send_count[w] = cnt.astype(np.int32)
        remote += int(cnt.sum() - cnt[w])
        # receive side: peer w sends to owner p its u entries owned by p
        for p in range(W):
            mine = u[owners_u == p]
            recv_local[p, w, : len(mine)] = (mine - p * n_loc).astype(np.int32)

    # autotuned segment-combine block plan: block sizes chosen from the
    # edge distribution, per-worker chunk tables built against the
    # kernel's padded view (repro.kernels.ops.plan_chunks). Imported
    # lazily: the kernels package pulls in repro.core, which imports the
    # channel modules that import this one.
    from repro.kernels import ops as kops

    block_rows, block_edges = kops.autotune_block_sizes(u_cap, e_cap)
    chunk_start, chunk_count, max_chunks = [], [], 0
    for w in range(W):
        cs, nc, mx = kops.plan_chunks(
            edge_seg[w], u_cap, block_rows, block_edges
        )
        chunk_start.append(cs)
        chunk_count.append(nc)
        max_chunks = max(max_chunks, mx)
    # max_chunks is a static grid bound derived from the edge *skew*, not
    # the caps — bucket it to the next power of two so same-cap graphs
    # with slightly different skew still share a compile signature
    max_chunks = _bucket_cap(max_chunks, 1)

    return ScatterPlan(
        edge_src=jnp.asarray(edge_src),
        edge_seg=jnp.asarray(edge_seg),
        edge_w=jnp.asarray(edge_w) if edge_w is not None else None,
        pack_slot=jnp.asarray(pack_slot),
        recv_local=jnp.asarray(recv_local),
        send_count=jnp.asarray(send_count),
        chunk_start=jnp.asarray(np.stack(chunk_start)),
        chunk_count=jnp.asarray(np.stack(chunk_count)),
        n_loc=n_loc,
        num_workers=W,
        e_cap=e_cap,
        u_cap=u_cap,
        slot_cap=c,
        remote_entries=remote,
        total_edges=total,
        block_rows=block_rows,
        block_edges=block_edges,
        max_chunks=max_chunks,
        hub_local=(jnp.asarray(hub_local_np)
                   if hub_local_np is not None else None),
        hub_cap=hub_cap,
        mirrored_edges=mirrored,
    )


def _build_prop_plan(
    src_new, dst_new, weights, n_workers, n_loc, align=8,
    mirror_threshold=None,
) -> PropPlan:
    W = n_workers
    owner_s = src_new // n_loc
    owner_d = dst_new // n_loc
    internal = owner_s == owner_d
    cut = ~internal

    # internal CSR (per worker, sorted by local dst)
    ei = 0
    per_worker = []
    for w in range(W):
        sel = internal & (owner_s == w)
        s = (src_new[sel] - w * n_loc).astype(np.int32)
        d = (dst_new[sel] - w * n_loc).astype(np.int32)
        wt = weights[sel] if weights is not None else None
        order = np.lexsort((s, d))
        per_worker.append((s[order], d[order], wt[order] if wt is not None else None))
        ei = max(ei, len(s))
    ei_cap = _bucket_cap(ei, align)
    int_src = np.zeros((W, ei_cap), np.int32)
    int_dst = np.full((W, ei_cap), n_loc, np.int32)
    int_w = np.zeros((W, ei_cap), np.float32) if weights is not None else None
    for w in range(W):
        s, d, wt = per_worker[w]
        int_src[w, : len(s)] = s
        int_dst[w, : len(d)] = d
        if int_w is not None and len(s):
            int_w[w, : len(s)] = wt

    cut_plan = _build_scatter_plan(
        src_new[cut], dst_new[cut],
        weights[cut] if weights is not None else None,
        n_workers, n_loc, align, mirror_threshold=mirror_threshold,
    )
    return PropPlan(
        int_src=jnp.asarray(int_src),
        int_dst=jnp.asarray(int_dst),
        int_w=jnp.asarray(int_w) if int_w is not None else None,
        cut=cut_plan,
        ei_cap=ei_cap,
    )


def _build_raw_edges(src_new, dst_new, weights, n_workers, n_loc, align=8) -> RawEdges:
    W = n_workers
    owner = src_new // n_loc
    counts = [int((owner == w).sum()) for w in range(W)]
    e_cap = _bucket_cap(max(counts, default=0), align)
    src_l = np.zeros((W, e_cap), np.int32)
    dst_g = np.zeros((W, e_cap), np.int32)
    ws = np.zeros((W, e_cap), np.float32) if weights is not None else None
    mask = np.zeros((W, e_cap), bool)
    for w in range(W):
        sel = owner == w
        e = int(sel.sum())
        src_l[w, :e] = (src_new[sel] - w * n_loc).astype(np.int32)
        dst_g[w, :e] = dst_new[sel].astype(np.int32)
        if ws is not None and e:
            ws[w, :e] = weights[sel]
        mask[w, :e] = True
    return RawEdges(
        src_local=jnp.asarray(src_l),
        dst_global=jnp.asarray(dst_g),
        w=jnp.asarray(ws) if ws is not None else None,
        mask=jnp.asarray(mask),
        e_cap=e_cap,
    )


def validate_edge_list(g) -> None:
    """Reject graphs whose edges index outside ``[0, n)`` or whose
    weights are NaN/inf, with the offending positions in the message."""
    if g.n < 1:
        raise ValueError(f"graph must have at least one vertex, got n={g.n}")
    e = np.asarray(g.edges)
    if e.size:
        if e.ndim != 2 or e.shape[1] != 2:
            raise ValueError(
                f"edges must be (E, 2) (src, dst), got shape {e.shape}")
        bad = (e < 0) | (e >= g.n)
        if bad.any():
            rows = np.flatnonzero(bad.any(axis=1))[:5]
            raise ValueError(
                f"{int(bad.any(axis=1).sum())} edge endpoint(s) outside "
                f"[0, {g.n}) — first bad edges at rows {rows.tolist()}: "
                f"{e[rows].tolist()}")
    if g.weights is not None:
        w = np.asarray(g.weights)
        if w.shape[0] != e.shape[0]:
            raise ValueError(
                f"weights length {w.shape[0]} != num edges {e.shape[0]}")
        nonfinite = ~np.isfinite(w)
        if nonfinite.any():
            rows = np.flatnonzero(nonfinite)[:5]
            raise ValueError(
                f"{int(nonfinite.sum())} non-finite edge weight(s) "
                f"(NaN/inf) — first at rows {rows.tolist()}: "
                f"{w[rows].tolist()}")


def _route_cap_bound(src, dst, n_workers: int, n_loc: int) -> int:
    """Max over (sending worker, owner) pairs of the number of *unique*
    destinations — the provable per-peer occupancy bound for any deduping
    routed send whose destinations are edge endpoints (any frontier's
    unique dsts per owner is a subset of the full edge set's)."""
    if not len(src):
        return 0
    n_pad = n_workers * n_loc
    key = (src // n_loc).astype(np.int64) * n_pad + dst
    u = np.unique(key)
    pair = (u // n_pad) * n_workers + (u % n_pad) // n_loc
    return int(np.bincount(pair, minlength=n_workers * n_workers).max())


def resolve_mirror_threshold(g: EdgeList, mirror_threshold) -> Optional[int]:
    """``None`` -> no mirroring; ``"auto"`` -> a degree several times the
    mean (hubs in the power-law sense); an int passes through."""
    if mirror_threshold is None:
        return None
    if mirror_threshold == "auto":
        avg = len(g.edges) / max(g.n, 1)
        return max(64, int(8 * avg))
    return int(mirror_threshold)


def partition_graph(
    g: EdgeList,
    n_workers: int,
    partitioner: str = "random",
    seed: int = 0,
    build=("scatter_out",),
    align: int = 8,
    mirror_threshold=None,
) -> PartitionedGraph:
    """Partition + relabel a graph and precompute the requested plans.

    build: subset of {"scatter_out", "scatter_in", "prop_out", "prop_in"}.

    mirror_threshold: enable hub mirroring in the scatter/prop-cut plans —
    ``None`` (off, plans identical to previous builds), an int degree
    threshold, or ``"auto"``. A vertex whose degree in a plan's
    orientation (counted over the edges that plan covers) exceeds the
    threshold gets a mirror slot on every worker its cut edges touch; the
    mirror pre-combines locally and the hub's value is refreshed by one
    static broadcast per superstep. Final vertex outputs are bit-identical
    to the unmirrored build for order-insensitive combiners (min/max/or —
    wcc, sv, sssp); floating-point ``sum`` may round differently (the
    reduction regroups), so leave mirroring off for e.g. pagerank if
    bit-stability matters.

    Rejects malformed inputs up front — an out-of-range endpoint or a
    non-finite weight would otherwise corrupt the relabel/scatter plans
    silently (numpy fancy indexing wraps negatives) and surface steps
    later as wrong answers, not errors.
    """
    validate_edge_list(g)
    if partitioner not in partition_lib.PARTITIONERS:
        raise ValueError(
            f"unknown partitioner {partitioner!r}; known partitioners: "
            f"{sorted(partition_lib.PARTITIONERS)}")
    new_of_old = partition_lib.PARTITIONERS[partitioner](g, n_workers, seed)
    n_loc = _round_up(-(-g.n // n_workers), align)
    src = new_of_old[g.edges[:, 0]]
    dst = new_of_old[g.edges[:, 1]]
    w = g.weights
    thr = resolve_mirror_threshold(g, mirror_threshold)

    W = n_workers
    _check_int32_extent("partition (W * n_loc)", W * n_loc)
    v_mask = np.zeros((W, n_loc), bool)
    flat = v_mask.reshape(-1)
    flat[np.asarray(new_of_old)] = True
    deg = np.zeros(W * n_loc, np.int32)
    np.add.at(deg, src, 1)

    plans = {}
    if "scatter_out" in build:
        plans["scatter_out"] = _build_scatter_plan(
            src, dst, w, W, n_loc, align, mirror_threshold=thr)
    if "scatter_in" in build:
        plans["scatter_in"] = _build_scatter_plan(
            dst, src, w, W, n_loc, align, mirror_threshold=thr)
    if "prop_out" in build:
        plans["prop_out"] = _build_prop_plan(
            src, dst, w, W, n_loc, align, mirror_threshold=thr)
    if "prop_in" in build:
        plans["prop_in"] = _build_prop_plan(
            dst, src, w, W, n_loc, align, mirror_threshold=thr)
    if "raw_out" in build:
        plans["raw_out"] = _build_raw_edges(src, dst, w, W, n_loc, align)
    if "raw_in" in build:
        plans["raw_in"] = _build_raw_edges(dst, src, w, W, n_loc, align)

    route_cap = max(_route_cap_bound(src, dst, W, n_loc),
                    _route_cap_bound(dst, src, W, n_loc))
    route_cap = _bucket_cap(route_cap, align) if route_cap else 0

    return PartitionedGraph(
        v_mask=jnp.asarray(v_mask),
        deg_out=jnp.asarray(deg.reshape(W, n_loc)),
        scatter_out=plans.get("scatter_out"),
        scatter_in=plans.get("scatter_in"),
        prop_out=plans.get("prop_out"),
        prop_in=plans.get("prop_in"),
        raw_out=plans.get("raw_out"),
        raw_in=plans.get("raw_in"),
        n=g.n,
        num_workers=W,
        n_loc=n_loc,
        directed=g.directed,
        name=g.name,
        new_of_old=HostArray(new_of_old),
        route_cap=route_cap,
    )
