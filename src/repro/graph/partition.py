"""Vertex partitioners.

A partitioner returns a relabeling permutation ``new_of_old`` such that
worker(v) = new_of_old[v] // n_loc (contiguous block ownership in the new
id space). Because ownership is by contiguous block, a partitioner never
chooses *how many* vertices a worker owns — the block sizes are fixed by
(n, n_workers, align) — only *which* vertices co-reside:

  - ``block`` / ``random``: the degree-blind baselines (identity order and
    a uniform shuffle). On power-law inputs both concentrate hub edge mass
    on whichever worker draws the hubs, which inflates every per-worker
    plan cap (caps are maxima over workers).
  - ``bfs_blocks``: locality order (METIS stand-in used for the paper's
    "Wikipedia (P)" partitioned experiments) — consecutive BFS ids land on
    the same worker.
  - ``degree``: degree-aware balance — greedy longest-processing-time
    assignment on the degree-sorted vertex order, so each worker's block
    carries ~equal total degree. This is the R-MAT/power-law regime fix:
    the handful of super-hubs are dealt to distinct workers first, then
    the tail fills the blocks back to level. Pairs with hub mirroring
    (``pgraph.partition_graph(mirror_threshold=...)``).
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.graph.generators import EdgeList


def _block_sizes(n: int, n_workers: int, align: int = 8):
    """The fixed contiguous-block capacity of every worker — must mirror
    ``pgraph.partition_graph``'s ``n_loc = round_up(ceil(n/W), align)``
    (same ``align`` default)."""
    n_loc = (-(-n // n_workers) + align - 1) // align * align
    return n_loc, [max(0, min(n_loc, n - w * n_loc)) for w in range(n_workers)]


def block(g: EdgeList, n_workers: int, seed: int = 0) -> np.ndarray:
    return np.arange(g.n, dtype=np.int64)


def random(g: EdgeList, n_workers: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n).astype(np.int64)
    return perm


def degrees(g: EdgeList) -> np.ndarray:
    """(n,) total degree (out + in) — the per-vertex communication mass a
    partitioner should balance."""
    deg = np.zeros(g.n, np.int64)
    e = g.edges
    if len(e):
        deg += np.bincount(e[:, 0], minlength=g.n)
        deg += np.bincount(e[:, 1], minlength=g.n)
    return deg


def degree(g: EdgeList, n_workers: int, seed: int = 0) -> np.ndarray:
    """Degree-aware blocks: greedy LPT over the degree-sorted vertices.

    Vertices are visited in descending total-degree order; each goes to
    the least-loaded worker that still has block slots free (load = total
    degree assigned so far). The block counts are fixed (contiguous
    ownership), so the only freedom — which vertices co-reside — is spent
    leveling degree mass: on R-MAT the few super-hubs land on distinct
    workers before the power-law tail refills the blocks evenly, keeping
    every per-worker plan cap (``e_cap`` / ``slot_cap`` / the routed
    ``route_cap``) near the mean instead of the hub-induced max.
    Deterministic (ties break by vertex id; ``seed`` is unused).
    """
    n, W = g.n, n_workers
    deg = degrees(g)
    n_loc, caps = _block_sizes(n, W)
    order = np.argsort(-deg, kind="stable")  # hubs first, ties by id

    assign = np.empty(n, np.int64)
    fill = [0] * W
    heap = [(0, w) for w in range(W) if caps[w]]
    heapq.heapify(heap)
    for v in order:
        load, w = heapq.heappop(heap)
        assign[v] = w
        fill[w] += 1
        if fill[w] < caps[w]:
            heapq.heappush(heap, (load + int(deg[v]) + 1, w))

    # within a block keep ascending old-id order (locality-neutral,
    # stable); the blocks tile [0, n) exactly (only the last non-empty
    # block is partial), so this is a permutation of [0, n)
    new_of_old = np.empty(n, np.int64)
    for w in range(W):
        mine = np.flatnonzero(assign == w)
        new_of_old[mine] = w * n_loc + np.arange(len(mine))
    return new_of_old


def bfs_blocks(g: EdgeList, n_workers: int, seed: int = 0) -> np.ndarray:
    """Locality-preserving order: BFS visit order over the undirected view.

    Consecutive BFS ids land on the same worker, so partition-internal
    subgraphs are connected-ish — the property the propagation channel
    exploits (paper §IV-C3, 'users should preprocess the graph by tagging
    a partition ID').

    The BFS is a vectorized level-synchronous frontier sweep over the CSR
    arrays (gather all frontier adjacencies at once, first-occurrence
    dedup) — the interpreter-bound deque version took minutes at scale
    >= 18, which blocked the weak-scaling sweeps.
    """
    n = g.n
    # build undirected CSR
    e = g.edges
    both = np.concatenate([e, e[:, ::-1]], axis=0)
    order = np.argsort(both[:, 0], kind="stable")
    both = both[order]
    offsets = np.searchsorted(both[:, 0], np.arange(n + 1))
    nbrs = both[:, 1]

    visited = np.zeros(n, bool)
    visit_order = np.empty(n, np.int64)
    nxt = 0
    rng = np.random.default_rng(seed)
    start_order = rng.permutation(n)

    for s in start_order:
        if visited[s]:
            continue
        frontier = np.array([s], dtype=np.int64)
        visited[s] = True
        while frontier.size:
            visit_order[nxt:nxt + frontier.size] = frontier
            nxt += frontier.size
            # gather every frontier vertex's adjacency range in one shot
            starts = offsets[frontier]
            cnts = offsets[frontier + 1] - starts
            total = int(cnts.sum())
            if not total:
                break
            base = np.repeat(starts - np.concatenate(([0], np.cumsum(cnts)[:-1])), cnts)
            cand = nbrs[base + np.arange(total)]
            cand = cand[~visited[cand]]
            if not cand.size:
                break
            # first-occurrence dedup keeps the deque visit order
            # (parent order major, adjacency order minor)
            _, first = np.unique(cand, return_index=True)
            frontier = cand[np.sort(first)]
            visited[frontier] = True
    assert nxt == n
    new_of_old = np.empty(n, np.int64)
    new_of_old[visit_order] = np.arange(n, dtype=np.int64)
    return new_of_old


PARTITIONERS = {
    "block": block,
    "random": random,
    "bfs": bfs_blocks,
    "degree": degree,
}
