"""Vertex partitioners.

A partitioner returns a relabeling permutation ``new_of_old`` such that
worker(v) = new_of_old[v] // n_loc (contiguous block ownership in the new
id space). ``bfs_blocks`` is the locality partitioner (METIS stand-in used
for the paper's "Wikipedia (P)" partitioned experiments).
"""
from __future__ import annotations

import numpy as np

from repro.graph.generators import EdgeList


def block(g: EdgeList, n_workers: int, seed: int = 0) -> np.ndarray:
    return np.arange(g.n, dtype=np.int64)


def random(g: EdgeList, n_workers: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n).astype(np.int64)
    return perm


def bfs_blocks(g: EdgeList, n_workers: int, seed: int = 0) -> np.ndarray:
    """Locality-preserving order: BFS visit order over the undirected view.

    Consecutive BFS ids land on the same worker, so partition-internal
    subgraphs are connected-ish — the property the propagation channel
    exploits (paper §IV-C3, 'users should preprocess the graph by tagging
    a partition ID').
    """
    n = g.n
    # build undirected CSR
    e = g.edges
    both = np.concatenate([e, e[:, ::-1]], axis=0)
    order = np.argsort(both[:, 0], kind="stable")
    both = both[order]
    offsets = np.searchsorted(both[:, 0], np.arange(n + 1))
    nbrs = both[:, 1]

    new_of_old = np.full(n, -1, dtype=np.int64)
    nxt = 0
    rng = np.random.default_rng(seed)
    start_order = rng.permutation(n)
    from collections import deque

    for s in start_order:
        if new_of_old[s] >= 0:
            continue
        dq = deque([s])
        new_of_old[s] = nxt
        nxt += 1
        while dq:
            u = dq.popleft()
            for v in nbrs[offsets[u]:offsets[u + 1]]:
                if new_of_old[v] < 0:
                    new_of_old[v] = nxt
                    nxt += 1
                    dq.append(v)
    assert nxt == n
    return new_of_old


PARTITIONERS = {"block": block, "random": random, "bfs": bfs_blocks}
