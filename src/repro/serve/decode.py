"""Serving: prefill + decode steps and a batched generation loop."""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, moe_impl: Optional[Callable] = None,
                      unroll: bool = False):
    def prefill_step(params, batch, cache):
        logits, cache = M.forward(cfg, params, batch, cache=cache,
                                  moe_impl=moe_impl, unroll=unroll)
        return logits[:, -1], cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, moe_impl: Optional[Callable] = None,
                     temperature: float = 0.0, unroll: bool = False):
    def decode_step(params, cache, tokens, pos, rng):
        """tokens (B,1) -> (next (B,1), logits (B,V), new cache)."""
        logits, cache = M.forward(
            cfg, params, {"tokens": tokens}, cache=cache, cache_pos=pos,
            moe_impl=moe_impl, unroll=unroll,
        )
        last = logits[:, -1]
        if temperature > 0:
            nxt = jax.random.categorical(rng, last / temperature)
        else:
            nxt = jnp.argmax(last, axis=-1)
        return nxt[:, None].astype(jnp.int32), last, cache
    return decode_step


def generate(cfg: ModelConfig, params, prompts, max_new: int,
             temperature: float = 0.0, seed: int = 0,
             moe_impl: Optional[Callable] = None):
    """Greedy/sampled generation for a (B, S) prompt batch."""
    b, s = prompts.shape
    cache = M.init_cache(cfg, b, s + max_new)
    prefill = jax.jit(make_prefill_step(cfg, moe_impl))
    decode = jax.jit(make_decode_step(cfg, moe_impl, temperature))
    last, cache = prefill(params, {"tokens": prompts}, cache)
    if temperature > 0:
        tok = jax.random.categorical(
            jax.random.PRNGKey(seed), last / temperature)[:, None]
    else:
        tok = jnp.argmax(last, axis=-1)[:, None]
    tok = tok.astype(jnp.int32)
    out = [tok]
    rng = jax.random.PRNGKey(seed + 1)
    for i in range(max_new - 1):
        rng, sub = jax.random.split(rng)
        tok, _, cache = decode(params, cache, tok, jnp.asarray(s + i), sub)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
