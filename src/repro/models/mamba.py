"""Mamba2 / SSD (state-space duality) mixer — chunked matmul formulation.

The chunked form is the TPU-native adaptation: within-chunk work is dense
matmuls (MXU) and only the small per-head (P x N) states recur across
chunks (a lax.scan of length S/chunk). The single-token decode path is the
exact SSM recurrence and is tested for equivalence against the chunked
full-sequence forward.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def _segsum(a):
    """a: (..., L). Returns (..., L, L) with out[i,j] = sum_{j<k<=i} a[k]
    for j < i, 0 on diagonal, -inf above."""
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    lo = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(lo, diff, -jnp.inf)


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x (B,S,C), w (K,C). state: (B,K-1,C) past
    inputs for decode continuation. Returns (y, new_state)."""
    b, s, c = x.shape
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((b, k - 1, c), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    y = jnp.zeros((b, s, c), jnp.float32)
    for i in range(k):
        y = y + xp[:, i : i + s].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros((b, 0, c), x.dtype)
    return jax.nn.silu(y).astype(x.dtype), new_state


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, init_state=None):
    """SSD scan.

    x:  (B, S, H, P)   inputs per head
    dt: (B, S, H)      discretization (post-softplus)
    a:  (H,)           negative decay rates (=-exp(A_log))
    b_mat, c_mat: (B, S, N)  shared across heads (1 group)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    da = dtc * a[None, None, None, :]            # (B,C,L,H)
    da_cum = jnp.cumsum(da, axis=2)              # (B,C,L,H)
    # intra-chunk: Y_diag = (C B^T * L) (dt x)
    ldec = jnp.exp(_segsum(jnp.moveaxis(da, -1, 2)))  # (B,C,H,L,L)
    cb = jnp.einsum("bcln,bcmn->bclm", cc, bc)        # (B,C,L,L)
    dtx = xc * dtc[..., None]                         # (B,C,L,H,P)
    y_diag = jnp.einsum("bclm,bchlm,bcmhp->bclhp", cb, ldec, dtx)

    # chunk states: contribution of each chunk to its end-state
    decay_to_end = jnp.exp(da_cum[:, :, -1:, :] - da_cum)  # (B,C,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", bc, decay_to_end, dtx)

    # recur across chunks
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])  # (B,C,H)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def scan_fn(prev, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        new = st + prev * dec[..., None, None]
        return new, prev  # emit the state ENTERING this chunk

    final, entering = jax.lax.scan(
        scan_fn,
        init_state.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0)),
    )
    entering = jnp.moveaxis(entering, 0, 1)  # (B,C,H,P,N)

    # inter-chunk: Y_off = C . (decay-from-start * entering_state)
    state_decay = jnp.exp(da_cum)  # (B,C,L,H)
    y_off = jnp.einsum("bcln,bclh,bchpn->bclhp", cc, state_decay, entering)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final


def mamba_forward(cfg: ModelConfig, lp, x, *, cache=None, chunk: int = 128):
    """Full-sequence (train/prefill) Mamba2 block. Returns (y, new_cache)."""
    b, s, d = x.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z = x @ lp["wz"]
    xin = x @ lp["wx"]
    bproj = x @ lp["wb"]
    cproj = x @ lp["wc"]
    dt = jax.nn.softplus((x @ lp["wdt"]).astype(jnp.float32) + lp["dt_bias"])

    xin, conv_x_state = _causal_conv(xin, lp["conv_x"])
    bproj, conv_b_state = _causal_conv(bproj, lp["conv_b"])
    cproj, conv_c_state = _causal_conv(cproj, lp["conv_c"])

    a = -jnp.exp(lp["A_log"].astype(jnp.float32))
    pad = (-s) % chunk
    if pad:
        padf = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xin_p, dt_p, b_p, c_p = map(padf, (xin, dt, bproj, cproj))
    else:
        xin_p, dt_p, b_p, c_p = xin, dt, bproj, cproj

    y, final_state = ssd_chunked(
        xin_p.reshape(b, s + pad, h, p),
        dt_p.astype(jnp.float32),
        a,
        b_p.astype(jnp.float32),
        c_p.astype(jnp.float32),
        chunk,
    )
    y = y[:, :s].reshape(b, s, h * p)
    y = y + xin * jnp.repeat(lp["D"], p)[None, None, :]
    # gated RMSNorm (mamba2)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
    y = (yf * lp["ssm_norm"]).astype(x.dtype)
    out = y @ lp["out_proj"]

    new_cache = None
    if cache is not None:
        new_cache = {
            "ssm": final_state.astype(jnp.float32),
            "conv_x": conv_x_state,
            "conv_b": conv_b_state,
            "conv_c": conv_c_state,
        }
    return out, new_cache


def mamba_decode(cfg: ModelConfig, lp, x, cache):
    """Single-token recurrence. x: (B, 1, d). Returns (y, new_cache)."""
    b, _, d = x.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z = x @ lp["wz"]
    xin = x @ lp["wx"]
    bproj = x @ lp["wb"]
    cproj = x @ lp["wc"]
    dt = jax.nn.softplus((x @ lp["wdt"]).astype(jnp.float32) + lp["dt_bias"])

    xin, cx = _causal_conv(xin, lp["conv_x"], cache["conv_x"])
    bproj, cb_ = _causal_conv(bproj, lp["conv_b"], cache["conv_b"])
    cproj, cc_ = _causal_conv(cproj, lp["conv_c"], cache["conv_c"])

    a = -jnp.exp(lp["A_log"].astype(jnp.float32))  # (H,)
    da = dt[:, 0] * a[None, :]                      # (B,H)
    xh = xin[:, 0].reshape(b, h, p).astype(jnp.float32)
    bv = bproj[:, 0].astype(jnp.float32)            # (B,N)
    cv = cproj[:, 0].astype(jnp.float32)
    dtx = xh * dt[:, 0, :, None]                    # (B,H,P)
    st = cache["ssm"] * jnp.exp(da)[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", dtx, bv
    )
    y = jnp.einsum("bhpn,bn->bhp", st, cv).reshape(b, 1, h * p).astype(x.dtype)
    y = y + xin * jnp.repeat(lp["D"], p)[None, None, :]
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
    y = (yf * lp["ssm_norm"]).astype(x.dtype)
    out = y @ lp["out_proj"]
    return out, {"ssm": st, "conv_x": cx, "conv_b": cb_, "conv_c": cc_}
