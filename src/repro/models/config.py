"""Model configuration for the assigned architecture pool.

One dataclass covers dense GQA transformers, MoE, Mamba2 (SSD), and
hybrid (Jamba) stacks, plus frontend-stub modalities (audio frames /
vision patches). Layers are grouped into repeating *blocks* so the
forward pass can lax.scan over stacked block parameters (compile time
stays flat in depth).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0  # 0 => d_model // n_heads
    # --- attention ---
    rope: str = "standard"        # "standard" | "2d" | "none"
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_window: int = 0          # 0 = full attention; >0 = sliding window
    pos_embed: str = "none"       # "none" | "sinusoidal"
    # --- mlp ---
    activation: str = "swiglu"    # "swiglu" | "gelu"
    # --- moe ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_ff: int = 0        # shared-expert ffn width (qwen2-moe)
    moe_ff: int = 0               # routed-expert ffn width
    moe_every: int = 1            # MoE on layers with (i % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # --- ssm / hybrid ---
    ssm: bool = False             # attention-free (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0           # hybrid: attention on (i % attn_every ==
    attn_offset: int = 0          # attn_offset), mamba elsewhere. 0 = all attn
    # --- modality frontend (STUB per task spec) ---
    frontend: str = "none"        # "none" | "audio_frames" | "vision_patches"
    frontend_tokens: int = 0      # prepended patch/frame embeddings
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> List[Tuple[str, str]]:
        """Per-layer (mixer, mlp) kinds for the whole stack."""
        out = []
        for i in range(self.n_layers):
            if self.ssm and self.attn_every == 0:
                mixer = "mamba"
            elif self.attn_every > 0:
                mixer = "attn" if i % self.attn_every == self.attn_offset else "mamba"
            else:
                mixer = "attn"
            if self.d_ff == 0 and self.moe_experts == 0:
                mlp = "none"
            elif self.moe_experts > 0 and i % self.moe_every == self.moe_offset:
                mlp = "moe"
            else:
                mlp = "dense"
            out.append((mixer, mlp))
        return out

    def block_pattern(self) -> List[Tuple[str, str]]:
        """The repeating block of layer kinds (scan unit)."""
        kinds = self.layer_kinds()
        # find the smallest repeating period that divides n_layers
        for period in range(1, self.n_layers + 1):
            if self.n_layers % period:
                continue
            if all(kinds[i] == kinds[i % period] for i in range(self.n_layers)):
                return kinds[:period]
        return kinds

    @property
    def n_blocks(self) -> int:
        return self.n_layers // len(self.block_pattern())

    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode without O(S) full-
        attention KV on every layer growing quadratic prefill cost."""
        if self.ssm and self.attn_every == 0:
            return True
        if self.attn_every > 0:  # hybrid: few attention layers, rest SSM
            return True
        return self.attn_window > 0  # sliding window

    def num_params(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.hd
        total = self.vocab * d  # embed
        if not self.tie_embeddings:
            total += d * self.vocab
        total += d  # final norm
        for mixer, mlp in self.layer_kinds():
            total += d  # pre-mixer norm
            if mixer == "attn":
                qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                total += qkv + (self.n_heads * hd) * d
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
            else:
                din, n, h = self.d_inner, self.ssm_state, self.ssm_heads
                total += d * din * 2        # wz, wx
                total += 2 * d * n          # wb, wc
                total += d * h + h          # wdt + bias
                total += self.ssm_conv * (din + 2 * n)
                total += 2 * h              # A_log, D
                total += din                # gated norm
                total += din * d            # out_proj
            if mlp == "dense":
                total += d  # pre-mlp norm
                mult = 3 if self.activation == "swiglu" else 2
                total += mult * d * self.d_ff
            elif mlp == "moe":
                total += d  # pre-mlp norm
                total += d * self.moe_experts  # router
                mult = 3 if self.activation == "swiglu" else 2
                total += self.moe_experts * mult * d * self.moe_ff
                if self.moe_shared_ff:
                    total += mult * d * self.moe_shared_ff + d
        return total

    def active_params(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.moe_experts == 0:
            return self.num_params()
        d = self.d_model
        mult = 3 if self.activation == "swiglu" else 2
        per_expert = mult * d * self.moe_ff
        n_moe_layers = sum(1 for _, m in self.layer_kinds() if m == "moe")
        inactive = n_moe_layers * (self.moe_experts - self.moe_top_k) * per_expert
        return self.num_params() - inactive
