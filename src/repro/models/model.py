"""The decoder stack: scan-over-blocks forward with train / prefill /
decode modes, frontend stubs, and pluggable MoE implementation (the SPMD
dry-run injects the shard_map channel version)."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain, residual_spec
from repro.models import layers, mamba
from repro.models.config import ModelConfig


def compute_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _cast_params(params, dtype):
    """Cast matmul weights to the compute dtype; keep vectors in fp32."""
    return jax.tree_util.tree_map(
        lambda a: a.astype(dtype) if a.ndim >= 2 else a, params
    )


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=None):
    """Decode cache pytree; leaves stacked over blocks."""
    dtype = dtype or compute_dtype(cfg)
    nb = cfg.n_blocks
    h, p, n = cfg.ssm_heads, cfg.ssm_state and cfg.ssm_head_dim, cfg.ssm_state
    caches = {}
    for li, (mixer, _) in enumerate(cfg.block_pattern()):
        if mixer == "attn":
            s_kv = min(s_max, cfg.attn_window) if cfg.attn_window else s_max
            caches[f"l{li}"] = {
                "k": jnp.zeros((nb, batch, s_kv, cfg.n_kv_heads, cfg.hd), dtype),
                "v": jnp.zeros((nb, batch, s_kv, cfg.n_kv_heads, cfg.hd), dtype),
            }
        else:
            kc = cfg.ssm_conv - 1
            caches[f"l{li}"] = {
                "ssm": jnp.zeros(
                    (nb, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32,
                ),
                "conv_x": jnp.zeros((nb, batch, kc, cfg.d_inner), dtype),
                "conv_b": jnp.zeros((nb, batch, kc, cfg.ssm_state), dtype),
                "conv_c": jnp.zeros((nb, batch, kc, cfg.ssm_state), dtype),
            }
    return caches


def cache_specs(cfg: ModelConfig, batch: int, s_max: int, dtype=None):
    return jax.eval_shape(lambda: init_cache(cfg, batch, s_max, dtype))


def embed_input(cfg: ModelConfig, params, batch: Dict[str, Any], dtype):
    """Token embedding + frontend-stub embeddings (precomputed, per spec)."""
    parts = []
    if "embeds" in batch and batch["embeds"] is not None:
        parts.append(batch["embeds"].astype(dtype))
    if "tokens" in batch and batch["tokens"] is not None:
        emb = params["embed"].astype(dtype)
        parts.append(emb[batch["tokens"]])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.pos_embed == "sinusoidal":
        pos = jnp.arange(x.shape[1])
        x = x + layers.sinusoidal_pos(pos, cfg.d_model, dtype)[None]
    return x


def forward(
    cfg: ModelConfig,
    params,
    batch: Dict[str, Any],
    *,
    cache=None,
    cache_pos=None,
    remat: bool = False,
    moe_impl: Optional[Callable] = None,
    logits_f32: bool = True,
    unroll: bool = False,
):
    """Returns (logits (B,S,V), new_cache_or_None).

    Modes: train (cache=None), prefill (cache given, cache_pos=None),
    decode (cache + cache_pos given; batch carries 1 token).
    """
    dt = compute_dtype(cfg)
    p = _cast_params(params, dt)
    moe_fn = moe_impl or layers.moe_layer
    pattern = cfg.block_pattern()
    decode = cache_pos is not None

    x = embed_input(cfg, p, batch, dt)
    res_spec = ("dp", None, None) if decode else residual_spec()
    x = constrain(x, *res_spec)
    b, s, d = x.shape
    if decode:
        positions = jnp.reshape(cache_pos, (1,))
    else:
        positions = jnp.arange(s)

    def block_fn(x, bp_bc):
        bp, bc = bp_bc
        new_bc = {} if bc is not None else None
        for li, (mixer, mlp) in enumerate(pattern):
            lp = bp[f"l{li}"]
            lc = bc[f"l{li}"] if bc is not None else None
            h = layers.rms_norm(x, lp["norm_mixer"], cfg.norm_eps)
            if mixer == "attn":
                y, nc = layers.attention(
                    cfg, lp, h, positions=positions, cache=lc,
                    cache_pos=cache_pos,
                )
            else:
                if decode:
                    y, nc = mamba.mamba_decode(cfg, lp, h, lc)
                else:
                    y, nc = mamba.mamba_forward(cfg, lp, h, cache=lc)
            x = x + y
            if mlp != "none":
                h2 = layers.rms_norm(x, lp["norm_mlp"], cfg.norm_eps)
                if mlp == "dense":
                    y2 = layers.dense_mlp(cfg, lp["w1"], lp["w2"],
                                          lp.get("w3"), h2)
                else:
                    y2 = moe_fn(cfg, lp, h2)
                x = x + y2
            if new_bc is not None:
                new_bc[f"l{li}"] = nc
        x = constrain(x, *res_spec)
        return x, new_bc

    f = jax.checkpoint(block_fn) if remat else block_fn
    x, new_cache = jax.lax.scan(
        f, x, (p["blocks"], cache),
        unroll=cfg.n_blocks if unroll else 1,
    )

    x = layers.rms_norm(x, p["final_norm"], cfg.norm_eps)
    head = (p["embed"].T if cfg.tie_embeddings else p["lm_head"]).astype(dt)
    logits = x @ head
    if logits_f32:
        logits = logits.astype(jnp.float32)
    # keep logits vocab-sharded through the loss/sampling (no (B,S,V) gather)
    logits = constrain(logits, "dp", None, "tp")
    return logits, new_cache
