"""Parameter tree builder.

One builder, three uses (same structure guaranteed):
  - init:  make() returns initialized jnp arrays;
  - specs: make() returns ShapeDtypeStruct (for jax.eval_shape / dry-run);
  - axes:  make() returns the logical-axis tuple (for the sharding policy).

Logical axes (mapped to mesh axes by repro.distributed.sharding):
  "fsdp"    — weight dim sharded over the data(+pod) axes (ZeRO-3 style)
  "tp"      — weight dim sharded over the model axis (tensor parallel)
  "ep"      — expert dim sharded over the model axis (expert parallel)
  None      — replicated
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def _normal(key, shape, dtype, scale):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def build(cfg: ModelConfig, make: Callable):
    """make(path: str, shape: tuple, axes: tuple, init: str) -> leaf."""
    d, hd = cfg.d_model, cfg.hd
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    p = {}
    p["embed"] = make("embed", (cfg.vocab, d), ("tp", "fsdp"), "embed")
    if not cfg.tie_embeddings:
        p["lm_head"] = make("lm_head", (d, cfg.vocab), ("fsdp", "tp"), "proj_in")
    p["final_norm"] = make("final_norm", (d,), (None,), "one")

    pattern = cfg.block_pattern()
    layers = {}
    for li, (mixer, mlp) in enumerate(pattern):
        lp = {}
        lp["norm_mixer"] = make(f"b{li}.norm_mixer", (cfg.n_blocks, d),
                                (None, None), "one")
        if mixer == "attn":
            lp["wq"] = make(f"b{li}.wq", (cfg.n_blocks, d, hq * hd),
                            (None, "fsdp", "tp"), "proj_in")
            lp["wk"] = make(f"b{li}.wk", (cfg.n_blocks, d, hkv * hd),
                            (None, "fsdp", "tp"), "proj_in")
            lp["wv"] = make(f"b{li}.wv", (cfg.n_blocks, d, hkv * hd),
                            (None, "fsdp", "tp"), "proj_in")
            lp["wo"] = make(f"b{li}.wo", (cfg.n_blocks, hq * hd, d),
                            (None, "tp", "fsdp"), "proj_out")
            if cfg.qkv_bias:
                lp["bq"] = make(f"b{li}.bq", (cfg.n_blocks, hq * hd),
                                (None, "tp"), "zero")
                lp["bk"] = make(f"b{li}.bk", (cfg.n_blocks, hkv * hd),
                                (None, "tp"), "zero")
                lp["bv"] = make(f"b{li}.bv", (cfg.n_blocks, hkv * hd),
                                (None, "tp"), "zero")
        elif mixer == "mamba":
            din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
            lp["wz"] = make(f"b{li}.wz", (cfg.n_blocks, d, din),
                            (None, "fsdp", "tp"), "proj_in")
            lp["wx"] = make(f"b{li}.wx", (cfg.n_blocks, d, din),
                            (None, "fsdp", "tp"), "proj_in")
            lp["wb"] = make(f"b{li}.wb", (cfg.n_blocks, d, n),
                            (None, "fsdp", None), "proj_in")
            lp["wc"] = make(f"b{li}.wc", (cfg.n_blocks, d, n),
                            (None, "fsdp", None), "proj_in")
            lp["wdt"] = make(f"b{li}.wdt", (cfg.n_blocks, d, h),
                             (None, "fsdp", None), "proj_in")
            lp["dt_bias"] = make(f"b{li}.dt_bias", (cfg.n_blocks, h),
                                 (None, None), "dt_bias")
            lp["conv_x"] = make(f"b{li}.conv_x", (cfg.n_blocks, cfg.ssm_conv, din),
                                (None, None, "tp"), "conv")
            lp["conv_b"] = make(f"b{li}.conv_b", (cfg.n_blocks, cfg.ssm_conv, n),
                                (None, None, None), "conv")
            lp["conv_c"] = make(f"b{li}.conv_c", (cfg.n_blocks, cfg.ssm_conv, n),
                                (None, None, None), "conv")
            lp["A_log"] = make(f"b{li}.A_log", (cfg.n_blocks, h),
                               (None, None), "a_log")
            lp["D"] = make(f"b{li}.D", (cfg.n_blocks, h), (None, None), "one")
            lp["ssm_norm"] = make(f"b{li}.ssm_norm", (cfg.n_blocks, din),
                                  (None, "tp"), "one")
            lp["out_proj"] = make(f"b{li}.out_proj", (cfg.n_blocks, din, d),
                                  (None, "tp", "fsdp"), "proj_out")
        if mlp == "dense":
            ff = cfg.d_ff
            lp["norm_mlp"] = make(f"b{li}.norm_mlp", (cfg.n_blocks, d),
                                  (None, None), "one")
            lp["w1"] = make(f"b{li}.w1", (cfg.n_blocks, d, ff),
                            (None, "fsdp", "tp"), "proj_in")
            lp["w2"] = make(f"b{li}.w2", (cfg.n_blocks, ff, d),
                            (None, "tp", "fsdp"), "proj_out")
            if cfg.activation == "swiglu":
                lp["w3"] = make(f"b{li}.w3", (cfg.n_blocks, d, ff),
                                (None, "fsdp", "tp"), "proj_in")
        elif mlp == "moe":
            e, ff = cfg.moe_experts, cfg.moe_ff
            lp["norm_mlp"] = make(f"b{li}.norm_mlp", (cfg.n_blocks, d),
                                  (None, None), "one")
            lp["router"] = make(f"b{li}.router", (cfg.n_blocks, d, e),
                                (None, "fsdp", None), "proj_in")
            # EP when E divides the model-axis size; else TP inside experts.
            lp["moe_w1"] = make(f"b{li}.moe_w1", (cfg.n_blocks, e, d, ff),
                                (None, "ep", "fsdp", "etp"), "proj_in")
            lp["moe_w2"] = make(f"b{li}.moe_w2", (cfg.n_blocks, e, ff, d),
                                (None, "ep", "etp", "fsdp"), "proj_out")
            if cfg.activation == "swiglu":
                lp["moe_w3"] = make(f"b{li}.moe_w3", (cfg.n_blocks, e, d, ff),
                                    (None, "ep", "fsdp", "etp"), "proj_in")
            if cfg.moe_shared_ff:
                sff = cfg.moe_shared_ff
                lp["shared_w1"] = make(f"b{li}.shared_w1", (cfg.n_blocks, d, sff),
                                       (None, "fsdp", "tp"), "proj_in")
                lp["shared_w2"] = make(f"b{li}.shared_w2", (cfg.n_blocks, sff, d),
                                       (None, "tp", "fsdp"), "proj_out")
                if cfg.activation == "swiglu":
                    lp["shared_w3"] = make(
                        f"b{li}.shared_w3", (cfg.n_blocks, d, sff),
                        (None, "fsdp", "tp"), "proj_in")
                lp["shared_gate"] = make(f"b{li}.shared_gate",
                                         (cfg.n_blocks, d, 1),
                                         (None, "fsdp", None), "proj_in")
        layers[f"l{li}"] = lp
    p["blocks"] = layers
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    """Random-init parameters (fp32 master by default)."""
    d = cfg.d_model
    counter = [0]

    def make(path, shape, axes, init):
        counter[0] += 1
        k = jax.random.fold_in(key, counter[0])
        if init == "zero":
            return jnp.zeros(shape, dtype)
        if init == "one":
            return jnp.ones(shape, dtype)
        if init == "embed":
            return _normal(k, shape, dtype, 0.02)
        if init == "proj_in":
            return _normal(k, shape, dtype, (1.0 / np.sqrt(shape[-2])))
        if init == "proj_out":
            return _normal(
                k, shape, dtype,
                1.0 / np.sqrt(shape[-2]) / np.sqrt(2.0 * cfg.n_layers),
            )
        if init == "conv":
            return _normal(k, shape, dtype, 0.02)
        if init == "a_log":
            # A in [1, 16) => A_log = log(A)
            u = jax.random.uniform(k, shape, minval=1.0, maxval=16.0)
            return jnp.log(u).astype(dtype)
        if init == "dt_bias":
            # dt in [1e-3, 1e-1] through softplus
            u = jax.random.uniform(k, shape, minval=np.log(1e-3), maxval=np.log(1e-1))
            dt = jnp.exp(u)
            return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
        raise ValueError(init)

    return build(cfg, make)


def param_specs(cfg: ModelConfig, dtype=jnp.float32):
    """ShapeDtypeStruct tree (no allocation — for the dry-run)."""
    return build(cfg, lambda path, shape, axes, init:
                 jax.ShapeDtypeStruct(shape, dtype))


def param_axes(cfg: ModelConfig):
    """Tree of logical-axis tuples matching the param tree."""
    return build(cfg, lambda path, shape, axes, init: axes)
