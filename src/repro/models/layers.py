"""Core layers: RMSNorm, RoPE, GQA attention (full + sliding window +
decode cache), dense MLP, MoE (sort-based capacity dispatch), Mamba2 SSD.

All functions are shape-polymorphic over (B, S, ...) and have explicit
single-token decode paths that are tested for equivalence against the
full-sequence forward.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

NEG_INF = -1e30


def rms_norm(x, w, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def sinusoidal_pos(positions, dim, dtype):
    """(S,) -> (S, dim) classic transformer sinusoids."""
    half = dim // 2
    freq = jnp.exp(-np.log(10_000.0) * jnp.arange(half) / half)
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def rope_tables(positions, rot_dim, theta):
    """positions (..., S) -> cos/sin (..., S, rot_dim/2)."""
    freq = theta ** (-jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, mode: str):
    """x: (B, S, H, hd). mode 'standard' rotates all dims (half-split
    layout); mode '2d' rotates only the first half of head dims
    (partial rotary, ChatGLM-style)."""
    if mode == "none":
        return x
    hd = x.shape[-1]
    rot = hd if mode == "standard" else hd // 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    r1 = x1 * c - x2 * s
    r2 = x2 * c + x1 * s
    return jnp.concatenate([r1, r2, xp], axis=-1).astype(x.dtype)


def _attn_scores_mask(q_pos, k_pos, window):
    """(..., Sq, Sk) additive mask: causal + optional sliding window."""
    ok = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] >= 0)
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def attention(cfg: ModelConfig, lp, x, *, positions, cache=None,
              cache_pos=None):
    """GQA attention.

    Train/prefill: cache=None or a cache dict to FILL (prefill).
    Decode: x is (B, 1, d); cache holds k/v; cache_pos is the write index.
    Returns (out, new_cache).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = hq // hkv
    dt = x.dtype

    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.qkv_bias:
        q = q + lp["bq"]
        k = k + lp["bk"]
        v = v + lp["bv"]
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)

    rot = hd if cfg.rope == "standard" else hd // 2
    if cfg.rope != "none":
        cos, sin = rope_tables(positions, rot, cfg.rope_theta)
        cos, sin = cos[None], sin[None]  # (1, S, rot/2)
        q = apply_rope(q, cos, sin, cfg.rope)
        k = apply_rope(k, cos, sin, cfg.rope)

    new_cache = None
    if cache is not None and cache_pos is not None:
        # decode: write this step's k/v into the (ring) cache
        s_max = cache["k"].shape[1]
        widx = cache_pos % s_max if cfg.attn_window > 0 else cache_pos
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, widx, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, widx, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k_full, v_full = ck, cv
        if cfg.attn_window > 0:
            k_pos = cache_pos - ((widx - jnp.arange(s_max)) % s_max)
        else:
            k_pos = jnp.arange(s_max)
        q_pos = positions
    elif cache is not None:
        # prefill: fill cache positions [0, s)
        s_max = cache["k"].shape[1]
        if cfg.attn_window > 0 and s > s_max:
            # ring invariant: position p lives at slot p % s_max
            tail_k = jnp.roll(k[:, -s_max:], shift=s % s_max, axis=1)
            tail_v = jnp.roll(v[:, -s_max:], shift=s % s_max, axis=1)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], tail_k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], tail_v.astype(cache["v"].dtype), (0, 0, 0, 0))
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k_full, v_full = k, v
        q_pos = positions
        k_pos = positions
    else:
        k_full, v_full = k, v
        q_pos = positions
        k_pos = positions

    # scores with GQA grouping: (b, hkv, g, sq, sk)
    qg = q.reshape(b, s, hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k_full.astype(jnp.float32))
    scores = scores / np.sqrt(hd)
    mask = _attn_scores_mask(q_pos, k_pos, cfg.attn_window)
    scores = scores + mask[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs,
                     v_full.astype(jnp.float32)).astype(dt)
    out = out.reshape(b, s, hq * hd)
    return out @ lp["wo"], new_cache


def dense_mlp(cfg: ModelConfig, w1, w2, w3, x):
    h = x @ w1
    if cfg.activation == "swiglu":
        h = jax.nn.silu(h) * (x @ w3)
    else:
        h = jax.nn.gelu(h)
    return h @ w2


def moe_local(cfg: ModelConfig, lp, x, *, expert_lo=0, n_local_experts=None,
              prefix="moe_"):
    """Sort-based capacity MoE over LOCAL tokens and LOCAL experts.

    x: (T, d) tokens. Expert params lp[prefix+"w1"...] hold the local slice
    (E_loc, d, ff_loc). Under SPMD this runs inside shard_map with tokens
    sharded over (pod, data) and experts (EP) or ff (expert-TP) sharded
    over model; the caller psums the result over the model axis. This is
    the request-respond channel pattern: dedup/sort by destination expert,
    capacity-bounded positional buffers, replies combined by weight.
    """
    t, d = x.shape
    e = cfg.moe_experts
    k = cfg.moe_top_k
    w1 = lp[prefix + "w1"]
    e_loc = n_local_experts if n_local_experts is not None else w1.shape[0]
    if t <= e:
        cap = t  # decode-sized batches: never drop (cap=t is collision-free)
    else:
        cap = max(int(np.ceil(t * k / e * cfg.capacity_factor)), 1)

    logits = (x @ lp["router"]).astype(jnp.float32)  # (T, E)
    topv, topi = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(topv, axis=-1)  # normalize over the top-k

    flat_e = topi.reshape(t * k)
    flat_w = weights.reshape(t * k)
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    mine = (flat_e >= expert_lo) & (flat_e < expert_lo + e_loc)
    e_rel = jnp.where(mine, flat_e - expert_lo, e_loc)
    order = jnp.argsort(e_rel)
    se = e_rel[order]
    stok = tok[order]
    sw = flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(e_loc + 1, dtype=jnp.int32))
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[jnp.clip(se, 0, e_loc)]
    fits = (se < e_loc) & (rank < cap)
    slot = jnp.where(fits, se * cap + rank, e_loc * cap)

    buf = jnp.zeros((e_loc * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(x[stok], mode="drop")[:-1]
    buf = buf.reshape(e_loc, cap, d)

    h = jnp.einsum("ecd,edf->ecf", buf, w1)
    if cfg.activation == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, lp[prefix + "w3"])
    else:
        h = jax.nn.gelu(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, lp[prefix + "w2"])

    out_flat = out_buf.reshape(e_loc * cap, d)
    out_flat = jnp.concatenate([out_flat, jnp.zeros((1, d), x.dtype)], 0)
    contrib = out_flat[slot] * sw[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[stok].add(
        jnp.where(fits[:, None], contrib, 0), mode="drop"
    )
    return y


def moe_layer(cfg: ModelConfig, lp, x):
    """MoE over (B, S, d) — local (single-shard) form. The SPMD dry-run
    wraps `moe_local` in shard_map instead (see distributed.moe_spmd)."""
    b, s, d = x.shape
    y = moe_local(cfg, lp, x.reshape(b * s, d))
    y = y.reshape(b, s, d)
    if cfg.moe_shared_ff:
        shared = dense_mlp(
            cfg, lp["shared_w1"], lp["shared_w2"], lp.get("shared_w3"), x
        )
        gate = jax.nn.sigmoid((x @ lp["shared_gate"]).astype(jnp.float32))
        y = y + shared * gate.astype(x.dtype)
    return y
