"""Architecture registry: the 10 assigned configs (exact numbers from the
public sources cited in the task), each with a reduced smoke config and
per-shape applicability (long_500k only for sub-quadratic archs, per the
task spec — skips documented in DESIGN.md §Arch-applicability)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.shapes import ALL_SHAPES, ShapeSpec
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    smoke: ModelConfig
    train_microbatches: int = 1  # gradient-accumulation chunks for train_4k


def _smoke(cfg: ModelConfig, **over) -> ModelConfig:
    """Reduced same-family config: few layers/width, tiny vocab."""
    base = dict(
        name=cfg.name + "-smoke",
        n_layers=max(2, len(cfg.block_pattern())),
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=101,
        head_dim=16,
        rope=cfg.rope,
        rope_theta=cfg.rope_theta,
        qkv_bias=cfg.qkv_bias,
        attn_window=min(cfg.attn_window, 8) if cfg.attn_window else 0,
        pos_embed=cfg.pos_embed,
        activation=cfg.activation,
        moe_experts=min(cfg.moe_experts, 8) if cfg.moe_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        moe_shared_ff=64 if cfg.moe_shared_ff else 0,
        moe_ff=32 if cfg.moe_ff else 0,
        moe_every=cfg.moe_every,
        moe_offset=cfg.moe_offset,
        capacity_factor=8.0,
        ssm=cfg.ssm,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_expand=cfg.ssm_expand,
        ssm_conv=cfg.ssm_conv,
        attn_every=cfg.attn_every,
        attn_offset=min(cfg.attn_offset, max(0, cfg.attn_every - 1)),
        frontend=cfg.frontend,
        frontend_tokens=4 if cfg.frontend_tokens else 0,
        tie_embeddings=cfg.tie_embeddings,
        dtype="float32",
    )
    base.update(over)
    c = ModelConfig(**base)
    # keep the hybrid pattern length dividing n_layers
    if cfg.attn_every:
        c = dataclasses.replace(c, n_layers=cfg.attn_every)
    return c


# --- the 10 assigned architectures (exact configs) ---

MUSICGEN_MEDIUM = ModelConfig(
    # [arXiv:2306.05284; hf] decoder-only over EnCodec tokens; frontend stub
    name="musicgen-medium", n_layers=48, d_model=1536, n_heads=24,
    n_kv_heads=24, d_ff=6144, vocab=2048, activation="gelu", rope="none",
    pos_embed="sinusoidal", frontend="audio_frames",
)

MAMBA2_130M = ModelConfig(
    # [arXiv:2405.21060] SSD; d_inner=1536, headdim=64 => 24 ssm heads
    name="mamba2-130m", n_layers=24, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=0, vocab=50280, ssm=True, ssm_state=128, ssm_head_dim=64,
    rope="none", tie_embeddings=True,
)

CHATGLM3_6B = ModelConfig(
    # [arXiv:2406.12793; hf] 2d (partial) RoPE, GQA kv=2, qkv bias
    name="chatglm3-6b", n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024, rope="2d", qkv_bias=True,
)

GRANITE_8B = ModelConfig(
    # [arXiv:2405.04324; hf] llama-arch code model
    name="granite-8b", n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152,
)

QWEN15_32B = ModelConfig(
    # [hf:Qwen/Qwen1.5 family] MHA (kv=40), QKV bias
    name="qwen1.5-32b", n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab=152064, qkv_bias=True,
)

QWEN2_7B = ModelConfig(
    # [arXiv:2407.10671; hf] GQA kv=4, QKV bias
    name="qwen2-7b", n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, qkv_bias=True,
)

MIXTRAL_8X7B = ModelConfig(
    # [arXiv:2401.04088; hf] 8 experts top-2, sliding window 4096
    name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, moe_experts=8, moe_top_k=2, moe_ff=14336,
    moe_every=1, attn_window=4096,
)

QWEN2_MOE_A27B = ModelConfig(
    # [hf:Qwen/Qwen1.5-MoE-A2.7B] 60 routed top-4 + 4 shared (5632 shared ff)
    name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=1408, vocab=151936, moe_experts=60, moe_top_k=4,
    moe_ff=1408, moe_shared_ff=5632, moe_every=1, qkv_bias=True,
)

INTERNVL2_2B = ModelConfig(
    # [arXiv:2404.16821; hf] InternViT stub + InternLM2 backbone
    name="internvl2-2b", n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, frontend="vision_patches", frontend_tokens=256,
)

JAMBA_15_LARGE = ModelConfig(
    # [arXiv:2403.19887; hf] 1:7 attn:mamba interleave, MoE 16e top-2
    name="jamba-1.5-large-398b", n_layers=72, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=24576, vocab=65536, moe_experts=16, moe_top_k=2,
    moe_ff=24576, moe_every=2, moe_offset=1, ssm_state=128, ssm_head_dim=64,
    attn_every=8, attn_offset=3,
)

ARCHS: Dict[str, ArchSpec] = {
    "musicgen-medium": ArchSpec(MUSICGEN_MEDIUM, _smoke(MUSICGEN_MEDIUM), 1),
    "mamba2-130m": ArchSpec(MAMBA2_130M, _smoke(MAMBA2_130M), 1),
    "chatglm3-6b": ArchSpec(CHATGLM3_6B, _smoke(CHATGLM3_6B), 2),
    "granite-8b": ArchSpec(GRANITE_8B, _smoke(GRANITE_8B), 2),
    "qwen1.5-32b": ArchSpec(QWEN15_32B, _smoke(QWEN15_32B), 4),
    "qwen2-7b": ArchSpec(QWEN2_7B, _smoke(QWEN2_7B), 2),
    "mixtral-8x7b": ArchSpec(MIXTRAL_8X7B, _smoke(MIXTRAL_8X7B), 4),
    "qwen2-moe-a2.7b": ArchSpec(QWEN2_MOE_A27B, _smoke(QWEN2_MOE_A27B), 1),
    "internvl2-2b": ArchSpec(INTERNVL2_2B, _smoke(INTERNVL2_2B), 1),
    "jamba-1.5-large-398b": ArchSpec(JAMBA_15_LARGE, _smoke(JAMBA_15_LARGE), 8),
}


def shape_applicable(arch: str, shape: ShapeSpec) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason string."""
    cfg = ARCHS[arch].config
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return ("pure full-attention arch: 500k-token decode needs "
                "sub-quadratic attention (skip per task spec)")
    return None


def cells(include_skipped=False):
    """All (arch, shape) dry-run cells."""
    out = []
    for arch in ARCHS:
        for shape in ALL_SHAPES.values():
            reason = shape_applicable(arch, shape)
            if reason is None or include_skipped:
                out.append((arch, shape, reason))
    return out
