"""One generic configuration-knob resolver for the data-plane surface.

Every tunable in this library answers the same question — "which concrete
implementation does this call site get?" — and every one of them answers
it with the same precedence ladder, most specific wins:

  1. an **explicit argument** at the call site (``use_kernel=False``);
  2. the knob's **scope** context manager (how ``Engine(...)`` threads a
     per-compile choice through a trace — trace-time, wrap the compile,
     not the execution);
  3. the knob's **environment variable** (``REPRO_*``);
  4. the **default** (a value, or a zero-arg callable evaluated at
     resolve time for backend-dependent defaults).

Before this module the ladder was copy-pasted per knob
(``kernels/ops.py`` for ``use_kernel``, ``core/routing.py`` twice for
``route_impl`` / ``route_batch``) — three chances for the precedence to
drift. A :class:`Knob` is the ladder as one object; the call sites keep
their public ``resolve_*`` / ``*_scope`` names as thin instance wrappers,
and the planner (``repro.plan``) enumerates the same instances to know
what it is allowed to decide.

Choice knobs (``choices=`` set) validate every resolved value and raise
``ValueError(f"unknown {describe} {value!r} (one of {choices})")`` — the
exact message the pre-unification resolvers raised, pinned by tests.
"""
from __future__ import annotations

import contextlib
import os
from typing import Any, Callable, Optional, Sequence, Tuple

_TRUTHY = ("1", "true", "yes", "on")


def parse_bool(text: str) -> bool:
    """Env-var truthiness: ``1/true/yes/on`` (case/space-insensitive)."""
    return text.strip().lower() in _TRUTHY


class Knob:
    """One configuration knob: explicit > scope > env > default.

    Args:
      name: the knob's canonical name (what plans/results report it as).
      env: environment variable consulted at step 3 (empty env values are
        treated as unset, matching ``os.environ.get(...) or default``).
      default: the fallback — a value, or a zero-arg callable evaluated
        per resolve (e.g. ``lambda: jax.default_backend() == "tpu"``).
      parse: maps the env string to a value (default: identity).
      coerce: normalizes explicit/scope values (e.g. ``bool``/``float``).
      choices: optional closed value set; anything outside it raises.
      describe: noun used in the rejection message (defaults to ``name``).
    """

    def __init__(self, name: str, *, env: Optional[str] = None,
                 default: Any = None,
                 parse: Callable[[str], Any] = lambda text: text,
                 coerce: Callable[[Any], Any] = lambda value: value,
                 choices: Optional[Sequence] = None,
                 describe: Optional[str] = None):
        self.name = name
        self.env = env
        self.default = default
        self.parse = parse
        self.coerce = coerce
        self.choices = None if choices is None else tuple(choices)
        self.describe = name if describe is None else describe
        self._override: Any = None

    def check(self, value):
        if self.choices is not None and value not in self.choices:
            raise ValueError(
                f"unknown {self.describe} {value!r} (one of {self.choices})")
        return value

    def _unset(self, value) -> bool:
        # None is the universal "not given"; for choice (string) knobs the
        # empty string also falls through, preserving the historical
        # ``value or override or env or default`` chaining.
        return value is None or (self.choices is not None and value == "")

    def resolve(self, value: Any = None):
        """The knob's value for a call site (see the module ladder)."""
        if not self._unset(value):
            return self.check(self.coerce(value))
        if self._override is not None:
            return self._override
        env = os.environ.get(self.env) if self.env else None
        if env:  # empty string == unset
            return self.check(self.parse(env))
        default = self.default() if callable(self.default) else self.default
        return self.check(self.coerce(default))

    @contextlib.contextmanager
    def scope(self, value: Any):
        """Pin the knob for everything resolved under the scope (None
        clears an outer override back to env/default). Scopes nest; each
        restores the previous override on exit."""
        prev = self._override
        self._override = None if self._unset(value) else self.resolve(value)
        try:
            yield
        finally:
            self._override = prev


def knob_values(knobs: Sequence[Knob]) -> Tuple[Tuple[str, Any], ...]:
    """Resolve a set of knobs to ``(name, value)`` pairs — the resolved
    configuration surface as data (what ``repro plan`` prints)."""
    return tuple((k.name, k.resolve()) for k in knobs)
