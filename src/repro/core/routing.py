"""Dynamic message routing — the exchange beneath the paper's standard
message channels (Table I).

Messages are (destination-global-id, payload) pairs with a validity mask.
Ownership is by contiguous id range, so a routed exchange only needs
*owner order*, not full destination order: each message's wire slot is
``owner * C + rank`` (rank = stable arrival rank within the owner
bucket), the packed (W, C, ...) buffer is exchanged with one tiled
``all_to_all``. The buffer is *per-owner*: the bucket router scatters
each message directly into its destination owner's C-wide tile, and the
tiled ``all_to_all`` splits those tiles across the mesh axis — no gather
through replicated memory, and under ``shard_map`` each device ships
exactly one tile per peer. C is the caller's per-peer capacity: the
partition layer's ``route_cap`` bound (``ChannelContext.edge_capacity``)
keeps it near the real per-owner occupancy instead of the full vertex
width, which is what makes the exchange weak-scale (see
``docs/scaling.md``). Two interchangeable implementations compute the
slots:

  - ``"bucket"`` (default): one-pass counting sort — per-owner histogram
    + stable rank + scatter. O(M·W) work / O(M) depth with the worker
    count W as the one-hot lane width, so it is the win whenever W is a
    modest constant (the regime of this library; at very large W the
    comparison narrows). Backed by the Pallas kernel
    (``repro.kernels.bucket_route``) on TPU and a pure-jnp reference
    elsewhere (``repro.kernels.ops.bucket_ranks`` decides, see the
    config surface there).
  - ``"sort"``: the legacy O(M log M) stable ``argsort`` over owners —
    kept as the measured baseline (``benchmarks/channel_dataplane.py``).

Both produce **bit-identical** ``Routed`` results (same slots, same
counts, same packing), so channels and compositions are oblivious to the
choice; select per call (``impl=``), per compile
(:func:`impl_scope` — what ``Engine(route_impl=...)`` uses), or via the
``REPRO_ROUTE_IMPL`` environment variable.

Used by DirectMessage / CombinedMessage / RequestRespond; the
scatter-combine channel avoids all of this via its static plan — that gap
is exactly the optimization the paper measures.

Traffic accounting contract: ``sent_count`` counts *wire* messages —
valid entries actually packed into a peer's capacity-bounded block
(post-dedup, since deduping channels route their deduped id list).
Enqueued sends beyond the capacity latch ``overflow`` but are never
charged: they never reach the wire.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap

from repro.configs import knobs
from repro.core.channel import TRAFFIC_DTYPE
from repro.kernels import ops as kops
from repro.pregel.errors import PlanRangeError

BIG = jnp.iinfo(jnp.int32).max


def _check_slot_range(w: int, capacity: int) -> None:
    """Wire slots are int32 ``owner * C + rank``: at production W x C the
    id silently wraps into another worker's range. W and C are trace-time
    python ints, so the bound is enforced before anything is compiled."""
    if w * capacity > BIG:
        raise PlanRangeError(
            f"routed exchange W * capacity = {w} * {capacity} exceeds the "
            f"int32 wire-slot range ({BIG}); reduce the per-peer capacity "
            "(e.g. a partition-derived ChannelContext.edge_capacity bound) "
            "or the worker count.",
            channels=("route",),
        )

IMPLS = ("bucket", "sort")

#: the routed-exchange implementation knob (explicit > impl_scope >
#: REPRO_ROUTE_IMPL > "bucket") — see repro.configs.knobs
ROUTE_IMPL = knobs.Knob(
    "route_impl", env="REPRO_ROUTE_IMPL", default="bucket",
    choices=IMPLS, describe="routing impl")


def resolve_impl(impl: Optional[str] = None) -> str:
    """The routing implementation for a call site: explicit argument,
    else the :func:`impl_scope` override, else ``REPRO_ROUTE_IMPL``,
    else ``"bucket"``."""
    return ROUTE_IMPL.resolve(impl)


def impl_scope(impl: Optional[str]):
    """Pin the routing impl for every route() under the scope
    (trace-time: wrap the compile, not the execution)."""
    return ROUTE_IMPL.scope(impl)


# --------------------------------------------------------------------------
# batched (query-lane) routing configuration — mirrors the impl surface
# --------------------------------------------------------------------------

BATCH_IMPLS = ("union", "lane")

#: the batched-routing strategy knob (explicit > batch_scope >
#: REPRO_ROUTE_BATCH > "union") — see repro.configs.knobs
ROUTE_BATCH = knobs.Knob(
    "route_batch", env="REPRO_ROUTE_BATCH", default="union",
    choices=BATCH_IMPLS, describe="route batch strategy")


def resolve_batch(batch: Optional[str] = None) -> str:
    """The batched-routing strategy for a call site: explicit argument,
    else the :func:`batch_scope` override, else ``REPRO_ROUTE_BATCH``,
    else ``"union"``.

      - ``"union"`` (default): per superstep, the routed channels compute
        the union frontier across the Q query lanes and run ONE
        bucket-route pass over it; payloads travel as a ``(slots, Q)``
        lane matrix with per-lane membership masks.
      - ``"lane"``: the PR-5 behavior — the query vmap batches the
        serial route, i.e. Q independent route passes per superstep.
        Kept as the measured baseline (``benchmarks/routed_batching.py``).
    """
    return ROUTE_BATCH.resolve(batch)


def batch_scope(batch: Optional[str]):
    """Pin the batched-routing strategy for every routed channel under
    the scope (trace-time: wrap the compile, not the execution) — how
    ``Engine(route_batch=...)`` threads the knob through a compile."""
    return ROUTE_BATCH.scope(batch)


def lane_live(ctx):
    """Per-lane liveness scalar for batched channel units: the runtime's
    pre-step halt vote, or constant True when none was provided (e.g. a
    hand-built test context)."""
    live = getattr(ctx, "query_live", None)
    return jnp.asarray(True) if live is None else jnp.asarray(live, bool)


@dataclasses.dataclass
class Routed:
    """Per-shard result of a routed exchange."""

    ids: jax.Array        # (W, C) int32 global dst ids received (BIG pad)
    mask: jax.Array       # (W, C) bool
    payload: Any          # pytree of (W, C, ...) arrays
    # sender-side bookkeeping for positional reply (RequestRespond):
    slot: jax.Array       # (M,) wire slot per ORIGINAL message (W*C = dropped)
    sent_count: jax.Array  # (W,) wire messages packed per peer
    overflow: jax.Array   # () bool — capacity exceeded (surfaced, not silent)


def _slots_sort(key, w: int):
    """Legacy baseline: stable argsort over owners, rank by position.
    Same (rank, count) contract as ``kops.bucket_ranks`` — validity is
    already encoded in ``key`` (invalid = the ``w`` sentinel); capacity
    is applied by the caller."""
    m = key.shape[0]
    order = jnp.argsort(key)  # stable: ties keep original order
    skey = key[order]
    bounds = jnp.searchsorted(
        skey, jnp.arange(w + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    pos = jnp.arange(m, dtype=jnp.int32)
    rank_sorted = pos - bounds[jnp.minimum(skey, w - 1)]
    # scatter ranks back to original message positions
    rank = jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted)
    return rank, bounds[1:] - bounds[:-1]


def route(
    ctx,
    dst,
    valid,
    payload,
    capacity: int,
    *,
    exchange_payload=True,
    impl: Optional[str] = None,
    use_kernel: Optional[bool] = None,
):
    """Route messages to the owners of their destination vertices.

    Args:
      ctx: ChannelContext (axis/W/n_loc).
      dst: (M,) int32 global destination ids.
      valid: (M,) bool.
      payload: pytree of (M, ...) arrays (may be empty dict).
      capacity: per-peer slot capacity C.
      impl: "bucket" | "sort" | None (resolve via scope/env/default).
      use_kernel: kernel-vs-reference for the bucket path (None = config).
    Returns:
      Routed — received ids/mask/payload plus sender bookkeeping.
    """
    W, n_loc, ax = ctx.num_workers, ctx.n_loc, ctx.axis
    c = capacity
    _check_slot_range(W, c)
    ids = jnp.where(valid, dst.astype(jnp.int32), BIG)
    owner = jnp.clip(ids // n_loc, 0, W - 1)
    key = jnp.where(valid, owner, W).astype(jnp.int32)

    if resolve_impl(impl) == "bucket":
        rank, count = kops.bucket_ranks(key, W, use_kernel=use_kernel)
    else:
        rank, count = _slots_sort(key, W)

    fits = rank < c
    overflow = jnp.any(valid & ~fits)
    slot = jnp.where(valid & fits, key * c + rank, W * c)
    # wire accounting: only packed messages cross the wire
    sent_count = jnp.minimum(count, c)

    def pack(leaf, fill):
        shape = (W * c + 1,) + leaf.shape[1:]
        buf = jnp.full(shape, fill, leaf.dtype)
        return buf.at[slot].set(leaf, mode="drop")[: W * c]

    send_ids = pack(ids, BIG).reshape(W, c)
    recv_ids = jax.lax.all_to_all(send_ids, ax, 0, 0, tiled=True)
    recv_mask = recv_ids != BIG

    if exchange_payload:
        def xch(leaf):
            packed = pack(leaf, 0).reshape((W, c) + leaf.shape[1:])
            return jax.lax.all_to_all(packed, ax, 0, 0, tiled=True)
        recv_payload = jax.tree_util.tree_map(xch, payload)
    else:
        recv_payload = None

    return Routed(
        ids=recv_ids,
        mask=recv_mask,
        payload=recv_payload,
        slot=slot,
        sent_count=sent_count,
        overflow=overflow,
    )


# --------------------------------------------------------------------------
# union-frontier batched routing (the query-aware data plane)
#
# Under the batched query plane the step function is vmapped over Q query
# lanes INSIDE the worker mapping, so a naive routed channel runs Q
# independent bucket-route passes over mostly-overlapping frontiers. The
# units below escape that vmap with ``jax.custom_batching.custom_vmap``:
# the batching rule sees all Q lanes materialized at once (batch at axis
# 0) while still under the worker trace, computes the UNION frontier,
# runs ONE bucket-route pass over it, and exchanges payloads as a
# ``(slots, Q)`` lane matrix with per-lane membership masks — one
# ``all_to_all`` per leaf instead of Q.
#
# Exactness contract: per-lane deliveries, ``sent_count`` and traffic are
# bit-identical to Q independent serial routes whenever the union pass
# does not overflow (union arrival ranks dominate per-lane ranks, so
# batched ``overflow`` is a conservative superset of serial overflow —
# never a silent drop). ``slot`` keeps its positional-reply semantics but
# holds *shared* wire slots, which differ from a lane's private ranks;
# only ``reply()`` consumes it and the round trip is order-exact.
# --------------------------------------------------------------------------


def union_dedup(dst_l, valid_l, n_total: int, u_cap: int):
    """:func:`dedup_dense` across Q lanes at once: the compact ascending
    unique list over the UNION of every lane's valid destinations.

    Args:
      dst_l: (Q, M) int32 global destination ids per lane.
      valid_l: (Q, M) bool.
      n_total: static id-space bound (W * n_loc).
      u_cap: compact-list capacity — ``min(Q * M, n_total)`` never
        truncates (the union cannot exceed either bound).
    Returns:
      (u_dst (u_cap,) ascending BIG-padded, pos (N,) compact index per id).
    """
    key_l = jnp.where(valid_l, dst_l.astype(jnp.int32), n_total)
    got = (
        jnp.zeros((n_total,), jnp.int32)
        .at[key_l.reshape(-1)]
        .add(1, mode="drop")
        > 0
    )
    pos = jnp.cumsum(got.astype(jnp.int32)) - 1
    u_dst = (
        jnp.full((u_cap + 1,), BIG, jnp.int32)
        .at[jnp.where(got, pos, u_cap)]
        .set(jnp.arange(n_total, dtype=jnp.int32), mode="drop")[:u_cap]
    )
    return u_dst, pos


def union_ranks(key, lanes, w: int, impl: Optional[str] = None,
                use_kernel: Optional[bool] = None):
    """Shared ranks + per-lane per-bucket counts over a union key list —
    the one route pass of the batched data plane. Same (rank, count)
    contract as the serial pass; ``lane_counts`` (W, Q) attributes wire
    occupancy to each lane for per-query traffic accounting."""
    if resolve_impl(impl) == "bucket":
        return kops.bucket_ranks_lanes(key, lanes, w, use_kernel=use_kernel)
    rank, count = _slots_sort(key, w)
    lane_counts = jax.ops.segment_sum(
        jnp.asarray(lanes, jnp.int32), key, w + 1)[:w]
    return rank, count, lane_counts


def route_union(
    ctx,
    dst,
    valid,
    payload,
    capacity: int,
    *,
    exchange_payload=True,
    impl: Optional[str] = None,
    use_kernel: Optional[bool] = None,
):
    """Batched :func:`route`: one shared bucket-route pass over the union
    frontier of all Q query lanes (see the section comment above).

    Call it exactly like ``route`` from inside a batched step (per-lane
    (M,) views); it returns the per-lane ``Routed`` view of the shared
    exchange. Positional union slots are only sound when ``dst`` is
    lane-invariant (graph topology, not query state) — proven via the
    custom_vmap ``in_batched`` flags; a lane-varying ``dst`` falls back
    to Q per-lane route passes inside the rule (same results, no
    sharing). Outside the batched query plane this IS ``route``.
    """
    if not getattr(ctx, "batched", False):
        return route(ctx, dst, valid, payload, capacity,
                     exchange_payload=exchange_payload, impl=impl,
                     use_kernel=use_kernel)
    impl = resolve_impl(impl)
    W, n_loc, ax = ctx.num_workers, ctx.n_loc, ctx.axis
    c = capacity
    _check_slot_range(W, c)
    leaves, treedef = jax.tree_util.tree_flatten(payload)

    def routed_tuple(r):
        pl_leaves = (jax.tree_util.tree_leaves(r.payload)
                     if exchange_payload else ())
        return (r.ids, r.mask, r.slot, r.sent_count, r.overflow, *pl_leaves)

    @custom_vmap
    def ex(qidx, live, dst, valid, *leaves):
        # unbatched trace (the runtime always vmaps over Q, so this body
        # only runs for a hand-called unbatched unit): the serial route
        r = route(ctx, dst, valid & live, treedef.unflatten(list(leaves)),
                  c, exchange_payload=exchange_payload, impl=impl,
                  use_kernel=use_kernel)
        return routed_tuple(r)

    @ex.def_vmap
    def _rule(axis_size, in_batched, qidx, live, dst, valid, *leaves):
        q = axis_size
        _, lb, db, vb = in_batched[:4]
        leaf_b = in_batched[4:]
        live2 = live if lb else jnp.broadcast_to(live, (q,))
        valid2 = valid if vb else jnp.broadcast_to(valid, (q,) + valid.shape)
        valid_eff = valid2 & live2[:, None]  # (Q, M)
        leaves2 = tuple(
            lf if b else jnp.broadcast_to(lf, (q,) + lf.shape)
            for lf, b in zip(leaves, leaf_b))

        if db:
            # dst varies per lane: positional sharing is unsound — run Q
            # per-lane serial routes (bit-identical, no union win)
            def one(d, v, lvs):
                r = route(ctx, d, v, treedef.unflatten(list(lvs)), c,
                          exchange_payload=exchange_payload, impl=impl,
                          use_kernel=use_kernel)
                return routed_tuple(r)

            outs = jax.vmap(one)(dst, valid_eff, leaves2)
            return outs, tuple(True for _ in outs)

        # ---- one shared pass over the union frontier ----
        uvalid = jnp.any(valid_eff, axis=0)  # (M,)
        ids = jnp.where(uvalid, dst.astype(jnp.int32), BIG)
        owner = jnp.clip(ids // n_loc, 0, W - 1)
        key = jnp.where(uvalid, owner, W).astype(jnp.int32)
        lanes = valid_eff.T  # (M, Q)
        rank, count, lane_counts = union_ranks(
            key, lanes, W, impl=impl, use_kernel=use_kernel)
        fits = rank < c
        packed = uvalid & fits
        slot = jnp.where(packed, key * c + rank, W * c)  # (M,) shared
        # per-lane views of the shared pass: overflow is conservative
        # (union ranks dominate lane ranks); sent counts are exact
        overflow_l = jnp.any(valid_eff & ~fits[None, :], axis=1)  # (Q,)
        sent_l = jnp.minimum(lane_counts, c).T  # (Q, W)
        slot_l = jnp.where(valid_eff & packed[None, :], slot[None, :], W * c)

        def pack(leafT, fill):  # leafT (M, ...) scattered at shared slots
            shape = (W * c + 1,) + leafT.shape[1:]
            buf = jnp.full(shape, fill, leafT.dtype)
            return buf.at[slot].set(leafT, mode="drop")[: W * c]

        send_ids = pack(ids, BIG).reshape(W, c)
        recv_ids = jax.lax.all_to_all(send_ids, ax, 0, 0, tiled=True)
        # per-lane wire membership rides as one (slots, Q) lane matrix
        send_mask = pack(lanes, False).reshape(W, c, q)
        recv_mask = jax.lax.all_to_all(send_mask, ax, 0, 0, tiled=True)
        out_mask = jnp.moveaxis(recv_mask, 2, 0)  # (Q, W, c)
        # a lane's ids view pads slots it did not occupy (= serial view)
        out_ids = jnp.where(out_mask, recv_ids[None], BIG)

        out = [out_ids, out_mask, slot_l, sent_l, overflow_l]
        if exchange_payload:
            for leaf2 in leaves2:  # (Q, M, ...)
                leafT = jnp.moveaxis(leaf2, 0, 1)  # (M, Q, ...)
                sel = lanes.reshape(lanes.shape + (1,) * (leafT.ndim - 2))
                leafT = jnp.where(sel, leafT, 0)  # serial pack fill
                buf = pack(leafT, 0).reshape((W, c, q) + leafT.shape[2:])
                recv = jax.lax.all_to_all(buf, ax, 0, 0, tiled=True)
                out.append(jnp.moveaxis(recv, 2, 0))  # (Q, W, c, ...)
        return tuple(out), tuple(True for _ in out)

    outs = ex(ctx.query_index, lane_live(ctx),
              jnp.asarray(dst, jnp.int32), valid, *leaves)
    ids, mask, slot, sent_count, overflow = outs[:5]
    recv_payload = (treedef.unflatten(list(outs[5:]))
                    if exchange_payload else None)
    return Routed(ids=ids, mask=mask, payload=recv_payload, slot=slot,
                  sent_count=sent_count, overflow=overflow)


def reply(ctx, routed: Routed, resp):
    """Send per-slot responses back (positionally — no ids on the wire)
    and deliver them in the original message order.

    Args:
      routed: the Routed from the request phase.
      resp: pytree of (W, C, ...) responses aligned with routed.ids.
    Returns:
      pytree of (M, ...) responses in original message order (zeros for
      messages that were never packed).
    """
    ax = ctx.axis

    def xch_back(leaf):
        back = jax.lax.all_to_all(leaf, ax, 0, 0, tiled=True)  # (W, C, ...)
        flat = back.reshape((-1,) + leaf.shape[2:])
        flat = jnp.concatenate([flat, jnp.zeros_like(flat[:1])], axis=0)
        # routed.slot is per original message: dropped slots hit the pad row
        return flat[jnp.minimum(routed.slot, flat.shape[0] - 1)]

    return jax.tree_util.tree_map(xch_back, resp)


def remote_count(ctx, sent_count):
    """Wire messages that actually cross a worker boundary (exclude self)."""
    me = ctx.me()
    return (sent_count.sum() - sent_count[me]).astype(TRAFFIC_DTYPE)


def dedup_dense(dst, valid, n_total: int, m_cap: Optional[int] = None):
    """Sort-free per-worker dedup: the compact ascending list of unique
    valid destinations, via a dense occupancy histogram + prefix-sum
    compaction (O(M + N) with an int32 N-sized transient — the counting
    idea of the bucket route applied to the id space; callers reduce
    values in the *compact* space, never densely).

    Regime note: the O(N) term is over the *global* id space, so it does
    not shrink as workers are added, while M = E/W does. Counting dedup
    wins whenever N is within a small factor of M (graphs with average
    degree >= ~2, the regime of this library and its benchmarks); for
    W*N >> E a sorted dedup would be the better trade — a future lever,
    switchable on the static (m, n_total) shapes at trace time.

    Args:
      dst: (M,) int32 global destination ids.
      valid: (M,) bool.
      n_total: static id-space bound (W * n_loc).
      m_cap: compact-list capacity (default M; the unique count never
        exceeds the valid count, so M is always safe).
    Returns:
      (u_dst, pos): ``u_dst`` (m_cap,) the unique destinations in
      ascending order, padded with BIG; ``pos`` (N,) the compact index of
      each destination id (arbitrary where the id never occurs).
    """
    m = dst.shape[0]
    m_cap = m if m_cap is None else m_cap
    key = jnp.where(valid, dst.astype(jnp.int32), n_total)
    got = jnp.zeros((n_total,), jnp.int32).at[key].add(1, mode="drop") > 0
    pos = jnp.cumsum(got.astype(jnp.int32)) - 1  # compact index per id
    u_dst = (
        jnp.full((m_cap + 1,), BIG, jnp.int32)
        .at[jnp.where(got, pos, m_cap)]
        .set(jnp.arange(n_total, dtype=jnp.int32), mode="drop")[:m_cap]
    )
    return u_dst, pos
