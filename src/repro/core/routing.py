"""Dynamic message routing — the exchange beneath the paper's standard
message channels (Table I).

Messages are (destination-global-id, payload) pairs with a validity mask.
Ownership is by contiguous id range, so a routed exchange only needs
*owner order*, not full destination order: each message's wire slot is
``owner * C + rank`` (rank = stable arrival rank within the owner
bucket), the packed (W, C, ...) buffer is exchanged with one tiled
``all_to_all``. Two interchangeable implementations compute the slots:

  - ``"bucket"`` (default): one-pass counting sort — per-owner histogram
    + stable rank + scatter. O(M·W) work / O(M) depth with the worker
    count W as the one-hot lane width, so it is the win whenever W is a
    modest constant (the regime of this library; at very large W the
    comparison narrows). Backed by the Pallas kernel
    (``repro.kernels.bucket_route``) on TPU and a pure-jnp reference
    elsewhere (``repro.kernels.ops.bucket_ranks`` decides, see the
    config surface there).
  - ``"sort"``: the legacy O(M log M) stable ``argsort`` over owners —
    kept as the measured baseline (``benchmarks/channel_dataplane.py``).

Both produce **bit-identical** ``Routed`` results (same slots, same
counts, same packing), so channels and compositions are oblivious to the
choice; select per call (``impl=``), per compile
(:func:`impl_scope` — what ``Engine(route_impl=...)`` uses), or via the
``REPRO_ROUTE_IMPL`` environment variable.

Used by DirectMessage / CombinedMessage / RequestRespond; the
scatter-combine channel avoids all of this via its static plan — that gap
is exactly the optimization the paper measures.

Traffic accounting contract: ``sent_count`` counts *wire* messages —
valid entries actually packed into a peer's capacity-bounded block
(post-dedup, since deduping channels route their deduped id list).
Enqueued sends beyond the capacity latch ``overflow`` but are never
charged: they never reach the wire.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.channel import TRAFFIC_DTYPE
from repro.kernels import ops as kops

BIG = jnp.iinfo(jnp.int32).max

IMPLS = ("bucket", "sort")

_IMPL_OVERRIDE: Optional[str] = None


def resolve_impl(impl: Optional[str] = None) -> str:
    """The routing implementation for a call site: explicit argument,
    else the :func:`impl_scope` override, else ``REPRO_ROUTE_IMPL``,
    else ``"bucket"``."""
    impl = impl or _IMPL_OVERRIDE or os.environ.get("REPRO_ROUTE_IMPL")
    impl = impl or "bucket"
    if impl not in IMPLS:
        raise ValueError(f"unknown routing impl {impl!r} (one of {IMPLS})")
    return impl


@contextlib.contextmanager
def impl_scope(impl: Optional[str]):
    """Pin the routing impl for every route() under the scope
    (trace-time: wrap the compile, not the execution)."""
    global _IMPL_OVERRIDE
    prev = _IMPL_OVERRIDE
    _IMPL_OVERRIDE = None if impl is None else resolve_impl(impl)
    try:
        yield
    finally:
        _IMPL_OVERRIDE = prev


@dataclasses.dataclass
class Routed:
    """Per-shard result of a routed exchange."""

    ids: jax.Array        # (W, C) int32 global dst ids received (BIG pad)
    mask: jax.Array       # (W, C) bool
    payload: Any          # pytree of (W, C, ...) arrays
    # sender-side bookkeeping for positional reply (RequestRespond):
    slot: jax.Array       # (M,) wire slot per ORIGINAL message (W*C = dropped)
    sent_count: jax.Array  # (W,) wire messages packed per peer
    overflow: jax.Array   # () bool — capacity exceeded (surfaced, not silent)


def _slots_sort(key, w: int):
    """Legacy baseline: stable argsort over owners, rank by position.
    Same (rank, count) contract as ``kops.bucket_ranks`` — validity is
    already encoded in ``key`` (invalid = the ``w`` sentinel); capacity
    is applied by the caller."""
    m = key.shape[0]
    order = jnp.argsort(key)  # stable: ties keep original order
    skey = key[order]
    bounds = jnp.searchsorted(
        skey, jnp.arange(w + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    pos = jnp.arange(m, dtype=jnp.int32)
    rank_sorted = pos - bounds[jnp.minimum(skey, w - 1)]
    # scatter ranks back to original message positions
    rank = jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted)
    return rank, bounds[1:] - bounds[:-1]


def route(
    ctx,
    dst,
    valid,
    payload,
    capacity: int,
    *,
    exchange_payload=True,
    impl: Optional[str] = None,
    use_kernel: Optional[bool] = None,
):
    """Route messages to the owners of their destination vertices.

    Args:
      ctx: ChannelContext (axis/W/n_loc).
      dst: (M,) int32 global destination ids.
      valid: (M,) bool.
      payload: pytree of (M, ...) arrays (may be empty dict).
      capacity: per-peer slot capacity C.
      impl: "bucket" | "sort" | None (resolve via scope/env/default).
      use_kernel: kernel-vs-reference for the bucket path (None = config).
    Returns:
      Routed — received ids/mask/payload plus sender bookkeeping.
    """
    W, n_loc, ax = ctx.num_workers, ctx.n_loc, ctx.axis
    c = capacity
    ids = jnp.where(valid, dst.astype(jnp.int32), BIG)
    owner = jnp.clip(ids // n_loc, 0, W - 1)
    key = jnp.where(valid, owner, W).astype(jnp.int32)

    if resolve_impl(impl) == "bucket":
        rank, count = kops.bucket_ranks(key, W, use_kernel=use_kernel)
    else:
        rank, count = _slots_sort(key, W)

    fits = rank < c
    overflow = jnp.any(valid & ~fits)
    slot = jnp.where(valid & fits, key * c + rank, W * c)
    # wire accounting: only packed messages cross the wire
    sent_count = jnp.minimum(count, c)

    def pack(leaf, fill):
        shape = (W * c + 1,) + leaf.shape[1:]
        buf = jnp.full(shape, fill, leaf.dtype)
        return buf.at[slot].set(leaf, mode="drop")[: W * c]

    send_ids = pack(ids, BIG).reshape(W, c)
    recv_ids = jax.lax.all_to_all(send_ids, ax, 0, 0, tiled=True)
    recv_mask = recv_ids != BIG

    if exchange_payload:
        def xch(leaf):
            packed = pack(leaf, 0).reshape((W, c) + leaf.shape[1:])
            return jax.lax.all_to_all(packed, ax, 0, 0, tiled=True)
        recv_payload = jax.tree_util.tree_map(xch, payload)
    else:
        recv_payload = None

    return Routed(
        ids=recv_ids,
        mask=recv_mask,
        payload=recv_payload,
        slot=slot,
        sent_count=sent_count,
        overflow=overflow,
    )


def reply(ctx, routed: Routed, resp):
    """Send per-slot responses back (positionally — no ids on the wire)
    and deliver them in the original message order.

    Args:
      routed: the Routed from the request phase.
      resp: pytree of (W, C, ...) responses aligned with routed.ids.
    Returns:
      pytree of (M, ...) responses in original message order (zeros for
      messages that were never packed).
    """
    ax = ctx.axis

    def xch_back(leaf):
        back = jax.lax.all_to_all(leaf, ax, 0, 0, tiled=True)  # (W, C, ...)
        flat = back.reshape((-1,) + leaf.shape[2:])
        flat = jnp.concatenate([flat, jnp.zeros_like(flat[:1])], axis=0)
        # routed.slot is per original message: dropped slots hit the pad row
        return flat[jnp.minimum(routed.slot, flat.shape[0] - 1)]

    return jax.tree_util.tree_map(xch_back, resp)


def remote_count(ctx, sent_count):
    """Wire messages that actually cross a worker boundary (exclude self)."""
    me = ctx.me()
    return (sent_count.sum() - sent_count[me]).astype(TRAFFIC_DTYPE)


def dedup_dense(dst, valid, n_total: int, m_cap: Optional[int] = None):
    """Sort-free per-worker dedup: the compact ascending list of unique
    valid destinations, via a dense occupancy histogram + prefix-sum
    compaction (O(M + N) with an int32 N-sized transient — the counting
    idea of the bucket route applied to the id space; callers reduce
    values in the *compact* space, never densely).

    Regime note: the O(N) term is over the *global* id space, so it does
    not shrink as workers are added, while M = E/W does. Counting dedup
    wins whenever N is within a small factor of M (graphs with average
    degree >= ~2, the regime of this library and its benchmarks); for
    W*N >> E a sorted dedup would be the better trade — a future lever,
    switchable on the static (m, n_total) shapes at trace time.

    Args:
      dst: (M,) int32 global destination ids.
      valid: (M,) bool.
      n_total: static id-space bound (W * n_loc).
      m_cap: compact-list capacity (default M; the unique count never
        exceeds the valid count, so M is always safe).
    Returns:
      (u_dst, pos): ``u_dst`` (m_cap,) the unique destinations in
      ascending order, padded with BIG; ``pos`` (N,) the compact index of
      each destination id (arbitrary where the id never occurs).
    """
    m = dst.shape[0]
    m_cap = m if m_cap is None else m_cap
    key = jnp.where(valid, dst.astype(jnp.int32), n_total)
    got = jnp.zeros((n_total,), jnp.int32).at[key].add(1, mode="drop") > 0
    pos = jnp.cumsum(got.astype(jnp.int32)) - 1  # compact index per id
    u_dst = (
        jnp.full((m_cap + 1,), BIG, jnp.int32)
        .at[jnp.where(got, pos, m_cap)]
        .set(jnp.arange(n_total, dtype=jnp.int32), mode="drop")[:m_cap]
    )
    return u_dst, pos
