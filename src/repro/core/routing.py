"""Dynamic sort-based message routing (the TPU stand-in for hash routing)
— the exchange beneath the paper's standard message channels (Table I).

Messages are (destination-global-id, payload) pairs with a validity mask.
Routing sorts by destination, buckets by owner (contiguous in the sorted
order because ownership is by id range), packs into a capacity-bounded
(W, C, ...) buffer and exchanges it with one tiled ``all_to_all``.

Used by DirectMessage / CombinedMessage / RequestRespond; the
scatter-combine channel avoids all of this via its static plan — that gap
is exactly the optimization the paper measures.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.channel import TRAFFIC_DTYPE

BIG = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass
class Routed:
    """Per-shard result of a routed exchange."""

    ids: jax.Array        # (W, C) int32 global dst ids received (BIG pad)
    mask: jax.Array       # (W, C) bool
    payload: Any          # pytree of (W, C, ...) arrays
    # sender-side bookkeeping for positional reply (RequestRespond):
    order: jax.Array      # (M,) argsort permutation used
    slot: jax.Array       # (M,) slot of each *sorted* message (W*C = dropped)
    sent_count: jax.Array  # (W,) messages sent per peer
    overflow: jax.Array   # () bool — capacity exceeded (surfaced, not silent)


def _pack(leaf_sorted, slot, cap, fill):
    shape = (cap + 1,) + leaf_sorted.shape[1:]
    buf = jnp.full(shape, fill, leaf_sorted.dtype)
    buf = buf.at[slot].set(leaf_sorted, mode="drop")
    return buf[:cap]


def route(ctx, dst, valid, payload, capacity: int, *, exchange_payload=True):
    """Route messages to the owners of their destination vertices.

    Args:
      ctx: ChannelContext (axis/W/n_loc).
      dst: (M,) int32 global destination ids.
      valid: (M,) bool.
      payload: pytree of (M, ...) arrays (may be empty dict).
      capacity: per-peer slot capacity C.
    Returns:
      Routed — received ids/mask/payload plus sender bookkeeping.
    """
    W, n_loc, ax = ctx.num_workers, ctx.n_loc, ctx.axis
    m = dst.shape[0]
    c = capacity
    key = jnp.where(valid, dst.astype(jnp.int32), BIG)
    order = jnp.argsort(key)
    sdst = key[order]
    svalid = sdst != BIG
    bounds = jnp.searchsorted(
        sdst, jnp.arange(W + 1, dtype=jnp.int32) * n_loc, side="left"
    ).astype(jnp.int32)
    owner = jnp.clip(sdst // n_loc, 0, W - 1)
    pos = jnp.arange(m, dtype=jnp.int32)
    slot_in = pos - bounds[owner]
    fits = slot_in < c
    overflow = jnp.any(svalid & ~fits)
    slot = jnp.where(svalid & fits, owner * c + slot_in, W * c)

    send_ids = _pack(sdst, slot, W * c, BIG).reshape(W, c)
    recv_ids = jax.lax.all_to_all(send_ids, ax, 0, 0, tiled=True)
    recv_mask = recv_ids != BIG

    sorted_payload = jax.tree_util.tree_map(lambda x: x[order], payload)
    if exchange_payload:
        def xch(leaf):
            packed = _pack(leaf, slot, W * c, 0).reshape((W, c) + leaf.shape[1:])
            return jax.lax.all_to_all(packed, ax, 0, 0, tiled=True)
        recv_payload = jax.tree_util.tree_map(xch, sorted_payload)
    else:
        recv_payload = None

    sent_count = bounds[1:] - bounds[:-1]
    return Routed(
        ids=recv_ids,
        mask=recv_mask,
        payload=recv_payload,
        order=order,
        slot=slot,
        sent_count=sent_count,
        overflow=overflow,
    )


def reply(ctx, routed: Routed, resp, m: int):
    """Send per-slot responses back (positionally — no ids on the wire) and
    un-permute to the original message order.

    Args:
      routed: the Routed from the request phase.
      resp: pytree of (W, C, ...) responses aligned with routed.ids.
      m: number of original messages.
    Returns:
      pytree of (M, ...) responses in original message order.
    """
    ax = ctx.axis

    def xch_back(leaf):
        back = jax.lax.all_to_all(leaf, ax, 0, 0, tiled=True)  # (W, C, ...)
        flat = back.reshape((-1,) + leaf.shape[2:])
        flat = jnp.concatenate([flat, jnp.zeros_like(flat[:1])], axis=0)
        per_sorted = flat[jnp.minimum(routed.slot, flat.shape[0] - 1)]
        out = jnp.zeros((m,) + per_sorted.shape[1:], per_sorted.dtype)
        return out.at[routed.order].set(per_sorted, mode="drop")

    return jax.tree_util.tree_map(xch_back, resp)


def remote_count(ctx, sent_count):
    """Messages that actually cross a worker boundary (exclude self)."""
    me = ctx.me()
    return (sent_count.sum() - sent_count[me]).astype(TRAFFIC_DTYPE)
