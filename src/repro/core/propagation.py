"""Propagation channel (paper §IV-C3).

Label-propagation algorithms converge in O(diameter) Pregel supersteps.
This channel runs a *local fixpoint* over partition-internal edges between
global exchanges (the block-centric / async-GAS effect), so the number of
global rounds drops to roughly the diameter of the quotient graph over
partitions. Only values that changed since the last exchange are counted
as traffic (the dense buffer is static — the accounting reflects the
logical messages a sparse implementation would send, matching how the
paper counts).

The combiner h must be commutative+associative and the update monotone
(min/max-style) for the fixpoint to be order-insensitive — the same
requirement the paper places on h.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import combiners as cb
from repro.core.channel import TRAFFIC_DTYPE, ChannelContext
from repro.graph.pgraph import PropPlan
from repro.kernels import ops as kops


def propagate(
    ctx: ChannelContext,
    plan: PropPlan,
    init_vals: jax.Array,
    combiner,
    *,
    edge_transform: Optional[Callable] = None,
    update: Optional[Callable] = None,
    src_values: Optional[Callable] = None,
    max_inner: int = 10_000,
    max_outer: int = 10_000,
    name: str = "propagation",
):
    """Run propagation to global convergence.

    Args:
      init_vals: (n_loc,) or (n_loc, D) initial labels.
      combiner: h — combines incoming neighbor values into the vertex value.
      edge_transform: fn(per_edge_vals, edge_w) — f applied along an edge
        (e.g. `lambda v, w: v + w` for SSSP).
      update: fn(lab, incoming) -> new lab (default: combiner(lab, inc)).
      src_values: fn(lab) -> per-vertex value broadcast to out-neighbors
        (default: identity; used e.g. to mask frozen vertices).
    Returns:
      (labels, outer_rounds, inner_iters_total)
    """
    combiner = cb.get(combiner)
    squeeze = init_vals.ndim == 1
    lab0 = init_vals[:, None] if squeeze else init_vals
    d = lab0.shape[-1]
    dtype = lab0.dtype
    ident = combiner.ident_for(dtype)
    w, c = ctx.num_workers, plan.cut.slot_cap
    n_loc = ctx.n_loc
    me = ctx.me()
    upd = update or (lambda lab, inc: combiner.fn(lab, inc))
    srcv = src_values or (lambda lab: lab)

    def edge_vals(lab, src_idx, ew):
        pe = srcv(lab)[src_idx]
        if edge_transform is not None:
            pe = edge_transform(pe, ew)
        return pe

    def local_fixpoint(lab):
        def cond(carry):
            _, changed, it = carry
            return changed & (it < max_inner)

        def body(carry):
            lab, _, it = carry
            pe = edge_vals(lab, plan.int_src, plan.int_w)
            inc = kops.segment_combine(pe, plan.int_dst, n_loc, combiner,
                                       use_kernel=False)
            new = upd(lab, inc)
            return new, jnp.any(new != lab), it + 1

        lab, _, iters = jax.lax.while_loop(
            cond, body, (lab, jnp.asarray(True), jnp.asarray(0, jnp.int32))
        )
        return lab, iters

    # owner of each unique cut destination (derivable from the static plan)
    u_owner = jnp.where(
        plan.cut.pack_slot < w * c, plan.cut.pack_slot // c, w
    )  # (U,) int32, w = padding

    # mirrored cut plans (partition_graph(mirror_threshold=...)): the cut
    # edge_src table indexes an extended value space — local values
    # followed by every worker's exported-hub values, refreshed by one
    # all_gather per exchange (same contract as scatter_combine). Only
    # hubs whose value changed since the last exchange count as traffic,
    # matching the channel's changed-only accounting.
    hub_cap = plan.cut.hub_cap
    if hub_cap:
        exported = plan.cut.hub_local < n_loc  # (hub_cap,)
        hub_safe = jnp.minimum(plan.cut.hub_local, n_loc - 1)

    def cut_edge_vals(lab, prev_hub):
        base = srcv(lab)
        changed_h = jnp.asarray(0, TRAFFIC_DTYPE)
        mine = prev_hub
        if hub_cap:
            mine = jnp.where(exported[:, None], base[hub_safe], ident)
            hubs = jax.lax.all_gather(mine, ctx.axis)  # (W, hub_cap, D)
            base = jnp.concatenate([base, hubs.reshape(-1, d)], axis=0)
            changed_h = jnp.sum(
                jnp.any(mine != prev_hub, axis=-1) & exported
            ).astype(TRAFFIC_DTYPE)
        pe = base[plan.cut.edge_src]
        if edge_transform is not None:
            pe = edge_transform(pe, plan.cut.edge_w)
        return pe, mine, changed_h

    def outer_body(carry):
        lab, prev_u, prev_hub, rounds, it_total, nbytes, nmsgs, _ = carry
        lab, iters = local_fixpoint(lab)

        # cut exchange (scatter-combine over cut edges, changed-only traffic)
        pe, new_hub, changed_h = cut_edge_vals(lab, prev_hub)
        u_vals = kops.segment_combine(
            pe, plan.cut.edge_seg, plan.cut.u_cap, combiner,
            use_kernel=False, assume_sorted=True,
        )
        changed_u = jnp.any(u_vals != prev_u, axis=-1) & (u_owner != w)
        remote_changed = jnp.sum(changed_u & (u_owner != me)).astype(TRAFFIC_DTYPE)
        buf = jnp.full((w * c + 1, d), ident, dtype)
        buf = buf.at[plan.cut.pack_slot].set(u_vals, mode="drop")
        recv = jax.lax.all_to_all(
            buf[: w * c].reshape(w, c, d), ctx.axis, 0, 0, tiled=True
        )
        inc = kops.segment_combine(
            recv.reshape(w * c, d), plan.cut.recv_local.reshape(-1), n_loc,
            combiner, use_kernel=False,
        )
        new = upd(lab, inc)
        changed = jax.lax.psum(jnp.any(new != lab).astype(jnp.int32), ctx.axis) > 0
        width = d * jnp.dtype(dtype).itemsize
        delta = remote_changed + changed_h * (w - 1)
        return (
            new, u_vals, new_hub, rounds + 1, it_total + iters,
            nbytes + delta * width, nmsgs + delta, changed,
        )

    def outer_cond(carry):
        _, _, _, rounds, _, _, _, changed = carry
        return changed & (rounds < max_outer)

    prev0 = jnp.full((plan.cut.u_cap, d), ident, dtype)
    prev_hub0 = jnp.full((hub_cap, d), ident, dtype)
    init = (
        lab0, prev0, prev_hub0, jnp.asarray(0, jnp.int32),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(0, TRAFFIC_DTYPE), jnp.asarray(0, TRAFFIC_DTYPE),
        jnp.asarray(True),
    )
    lab, _, _, rounds, iters, nbytes, nmsgs, _ = jax.lax.while_loop(
        outer_cond, outer_body, init
    )
    ctx.add_traffic(name, nbytes, nmsgs)
    return (lab[:, 0] if squeeze else lab), rounds, iters
