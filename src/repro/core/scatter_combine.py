"""Scatter-Combine channel (paper §IV-C1).

The *static messaging pattern*: every vertex sends a value to all of its
neighbors, every superstep, regardless of state. The channel preprocesses
the edges once (sorted by destination, sender-side dedup to one slot per
unique destination per worker, positional receive tables) so that each
superstep is: gather → sorted-segment combine (Pallas kernel on TPU) →
one all_to_all with **no vertex ids on the wire** → receive-side combine.

The exchange is exposed in two forms: :func:`broadcast_combine` performs
the whole superstep, while :func:`plan_broadcast_combine` returns a
``PlannedExchange`` split at the collective boundary so the composition
layer (``repro.core.compose.fused_exchange``, paper §V) can merge several
independent channels' exchanges into one collective round.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import combiners as cb
from repro.core import compose
from repro.core.channel import TRAFFIC_DTYPE, ChannelContext
from repro.graph.pgraph import ScatterPlan
from repro.kernels import ops as kops


def plan_broadcast_combine(
    ctx: ChannelContext,
    plan: ScatterPlan,
    vertex_vals: jax.Array,
    combiner,
    *,
    edge_transform: Optional[Callable] = None,
    use_kernel: Optional[bool] = None,
    name: str = "scatter_combine",
) -> compose.PlannedExchange:
    """Stage one scatter-combine superstep up to (but not including) the
    collective; see :func:`broadcast_combine` for argument semantics.

    Returns a ``PlannedExchange`` whose payload is the packed positional
    ``(W, C, D)`` send buffer and whose ``finish`` performs the
    receive-side combine. Execute it — alone or merged with other
    channels' planned exchanges — via ``compose.fused_exchange``.
    """
    combiner = cb.get(combiner)
    w, c = ctx.num_workers, plan.slot_cap
    squeeze = vertex_vals.ndim == 1
    vals = vertex_vals[:, None] if squeeze else vertex_vals
    d = vals.shape[-1]
    ident = combiner.ident_for(vals.dtype)

    # 1. per-edge values (gather by local src; padded edges dropped via seg
    # id). Mirrored plans (partition_graph(mirror_threshold=...)) extend
    # the gather index space with every worker's exported-hub values:
    # index n_loc + owner * hub_cap + hub_rank reads the mirror of a
    # remote hub. The mirror->master refresh is the *static* special case
    # of the RequestRespond channel — the request ids (each owner's
    # hub_local table) are precomputed into the plan and the respond phase
    # is positional, so the round trip collapses to one all_gather of the
    # (hub_cap, D) hub-value tables per superstep. Mirror traffic is
    # charged below under this channel's own stat key.
    mirror_msgs = jnp.zeros((), TRAFFIC_DTYPE)
    if plan.hub_cap:
        exported = plan.hub_local < ctx.n_loc  # (hub_cap,) real slots
        safe = jnp.minimum(plan.hub_local, ctx.n_loc - 1)
        mine = jnp.where(exported[:, None], vals[safe], ident)
        hubs = jax.lax.all_gather(mine, ctx.axis)  # (W, hub_cap, D)
        vals_ext = jnp.concatenate([vals, hubs.reshape(-1, d)], axis=0)
        mirror_msgs = (jnp.sum(exported) * (w - 1)).astype(TRAFFIC_DTYPE)
    else:
        vals_ext = vals
    per_edge = vals_ext[plan.edge_src]
    if edge_transform is not None:
        per_edge = edge_transform(per_edge, plan.edge_w)

    # 2. sender-side combine: one value per unique destination (sorted
    # ids). The kernel path rides the plan's autotuned block sizes and
    # precomputed chunk tables (graph/pgraph.py) instead of deriving a
    # worst-case grid on device.
    kernel_kw = {}
    if plan.chunk_start is not None:
        kernel_kw = dict(
            block_rows=plan.block_rows,
            block_edges=plan.block_edges,
            chunk_plan=(plan.chunk_start, plan.chunk_count, plan.max_chunks),
        )
    u_vals = kops.segment_combine(
        per_edge, plan.edge_seg, plan.u_cap, combiner,
        use_kernel=use_kernel, assume_sorted=True, **kernel_kw,
    )

    # 3. positional pack (payload only — the routing is static)
    buf = jnp.full((w * c + 1, d), ident, vals.dtype)
    buf = buf.at[plan.pack_slot].set(u_vals, mode="drop")
    send = buf[: w * c].reshape(w, c, d)

    # 4. (deferred) receive-side combine into dense per-vertex values
    def finish(recv):
        out = kops.segment_combine(
            recv["v"].reshape(w * c, d), plan.recv_local.reshape(-1),
            ctx.n_loc, combiner, use_kernel=False,
        )
        return out[:, 0] if squeeze else out

    me = ctx.me()
    remote = (plan.send_count.sum() - plan.send_count[me]).astype(TRAFFIC_DTYPE)
    remote = remote + mirror_msgs  # hub broadcast crosses (W-1) boundaries
    return compose.PlannedExchange(
        name=name,
        payload={"v": send},
        finish=finish,
        nbytes=remote * d * jnp.dtype(vals.dtype).itemsize,
        nmsgs=remote,
    )


def broadcast_combine(
    ctx: ChannelContext,
    plan: ScatterPlan,
    vertex_vals: jax.Array,
    combiner,
    *,
    edge_transform: Optional[Callable] = None,
    use_kernel: Optional[bool] = None,
    name: str = "scatter_combine",
) -> jax.Array:
    """One scatter-combine superstep.

    Args:
      plan: per-shard ScatterPlan (leading W axis already mapped away).
      vertex_vals: (n_loc,) or (n_loc, D) per-vertex value to broadcast.
      combiner: Combiner (receiver gets combine over in-neighbors).
      edge_transform: optional fn(per_edge_vals, edge_w) -> per_edge_vals
        (e.g. dist + weight for SSSP over a weighted plan).
    Returns:
      (n_loc,) or (n_loc, D) combined incoming value per local vertex
      (combiner identity where nothing arrived).
    """
    planned = plan_broadcast_combine(
        ctx, plan, vertex_vals, combiner,
        edge_transform=edge_transform, use_kernel=use_kernel, name=name,
    )
    (out,) = compose.fused_exchange(ctx, [planned])
    return out
