"""Aggregator channel (paper Table I): global reduction available to every
vertex next superstep. Lowers to a single mesh collective; traffic is
O(W * payload), which we account like the paper does (one value per
worker toward the master, broadcast back).

``all_halted`` is the runtime's voting-to-halt primitive: a device-side
psum whose result feeds the fused loop condition directly — no host
involvement per superstep."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import combiners as cb
from repro.core.channel import ChannelContext


def aggregate(
    ctx: ChannelContext,
    values: jax.Array,
    combiner,
    valid: Optional[jax.Array] = None,
    *,
    name: str = "aggregator",
):
    """Combine `values` over all vertices of all workers.

    Args:
      values: (n_loc, ...) per-vertex contributions.
      valid: (n_loc,) mask of contributing vertices (default: all).
    Returns:
      scalar/array: the global combined value (replicated on all workers).
    """
    combiner = cb.get(combiner)
    if valid is not None:
        mask = valid.reshape(valid.shape + (1,) * (values.ndim - valid.ndim))
        values = jnp.where(mask, values, combiner.ident_for(values.dtype))
    # local reduce then cross-worker reduce
    local = values
    if combiner.name == "sum":
        local = local.sum(axis=0)
    elif combiner.name == "min":
        local = local.min(axis=0)
    elif combiner.name == "max":
        local = local.max(axis=0)
    elif combiner.name == "or":
        local = local.any(axis=0)
    elif combiner.name == "prod":
        local = local.prod(axis=0)
    else:
        red = combiner.identity_like(local[0])
        for_fn = lambda i, acc: combiner.fn(acc, local[i])
        local = jax.lax.fori_loop(0, local.shape[0], for_fn, red)
    out = combiner.psum_like(local, ctx.axis)
    per = int(jnp.dtype(values.dtype).itemsize)
    for dim in values.shape[1:]:
        per *= int(dim)
    # 2(W-1) values on the wire: gather + broadcast
    ctx.add_traffic(name, 2 * (ctx.num_workers - 1) * per, 2 * (ctx.num_workers - 1))
    return out


def all_halted(ctx: ChannelContext, local_halt) -> jax.Array:
    """Voting-to-halt: true iff every worker votes halt."""
    votes = jax.lax.psum(jnp.asarray(local_halt, jnp.int32), ctx.axis)
    return votes == ctx.num_workers
