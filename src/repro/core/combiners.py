"""Combiners — associative/commutative reduction operators for channels
(paper Table I; the per-channel combiner parameter of every §IV-C channel).

The paper attaches a combiner to each channel independently (unlike Pregel's
single global combiner, which Table IV shows is inapplicable to
heterogeneous-message programs); every optimized channel in this library
is parameterized by one of these.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_static
@dataclasses.dataclass(frozen=True)
class Combiner:
    """An associative, commutative binary reduction with identity.

    Attributes:
      name: short tag ("sum" | "min" | "max" | "or" | "prod").
      fn: jnp-compatible binary op.
      identity: identity element (python scalar; cast to the value dtype).
    """

    name: str
    fn: Callable
    identity: float

    def __call__(self, a, b):
        return self.fn(a, b)

    def identity_like(self, x):
        if self.name == "min_by_first":
            # lexicographic-min over trailing dim: key = [..., 0]
            out = jnp.zeros_like(x)
            key_ident = (
                jnp.iinfo(x.dtype).max
                if jnp.issubdtype(x.dtype, jnp.integer)
                else jnp.inf
            )
            return out.at[..., 0].set(key_ident)
        return jnp.full_like(x, self.ident_for(x.dtype))

    def ident_for(self, dtype):
        dtype = jnp.dtype(dtype)
        if self.name in ("min", "min_by_first"):
            if jnp.issubdtype(dtype, jnp.integer):
                return jnp.iinfo(dtype).max
            return jnp.inf
        if self.name == "max":
            if jnp.issubdtype(dtype, jnp.integer):
                return jnp.iinfo(dtype).min
            return -jnp.inf
        return self.identity

    def segment_reduce(self, vals, seg_ids, num_segments):
        """Reference segment reduction (sorted or unsorted seg_ids)."""
        if self.name == "min_by_first":
            from repro.core import segmented

            order = jnp.argsort(seg_ids)
            return segmented.segmented_reduce_sorted(
                vals[order],
                jnp.asarray(seg_ids, jnp.int32)[order],
                num_segments,
                self.fn,
                self.identity_like,
            )
        if self.name == "sum":
            out = jax.ops.segment_sum(vals, seg_ids, num_segments)
        elif self.name == "min":
            out = jax.ops.segment_min(vals, seg_ids, num_segments)
        elif self.name == "max":
            out = jax.ops.segment_max(vals, seg_ids, num_segments)
        elif self.name == "prod":
            out = jax.ops.segment_prod(vals, seg_ids, num_segments)
        elif self.name == "or":
            out = jax.ops.segment_max(vals.astype(jnp.int32), seg_ids, num_segments)
            out = out.astype(vals.dtype)
        else:
            raise ValueError(f"unknown combiner {self.name}")
        # segment_min/max fill empty segments with the dtype extremum, which
        # already equals our identity; segment_sum fills 0 == identity.
        return out

    def psum_like(self, x, axis_name):
        """Cross-worker reduction matching this combiner."""
        if self.name == "sum":
            return jax.lax.psum(x, axis_name)
        if self.name == "min":
            return jax.lax.pmin(x, axis_name)
        if self.name == "max":
            return jax.lax.pmax(x, axis_name)
        if self.name == "or":
            return jax.lax.pmax(x.astype(jnp.int32), axis_name).astype(x.dtype)
        if self.name in ("prod", "min_by_first"):
            g = jax.lax.all_gather(x, axis_name)
            if self.name == "prod":
                return jnp.prod(g, axis=0)
            out = g[0]
            for i in range(1, g.shape[0]):
                out = self.fn(out, g[i])
            return out
        raise ValueError(self.name)


def _min_by_first(a, b):
    """Lexicographic argmin on the trailing dim's first component, carrying
    the rest of the vector as payload (Boruvka's (weight, src, dst))."""
    take_a = a[..., :1] <= b[..., :1]
    return jnp.where(take_a, a, b)


SUM = Combiner("sum", jnp.add, 0.0)
MIN = Combiner("min", jnp.minimum, np.inf)
MAX = Combiner("max", jnp.maximum, -np.inf)
OR = Combiner("or", jnp.logical_or, False)
PROD = Combiner("prod", jnp.multiply, 1.0)
MIN_BY_FIRST = Combiner("min_by_first", _min_by_first, np.inf)

BY_NAME = {c.name: c for c in (SUM, MIN, MAX, OR, PROD, MIN_BY_FIRST)}


def get(name_or_combiner) -> Combiner:
    if isinstance(name_or_combiner, Combiner):
        return name_or_combiner
    return BY_NAME[name_or_combiner]
