"""Channel base machinery: per-step context and message accounting.

The paper's ``Channel`` base class exposes serialize()/deserialize() hooks
around raw per-peer byte buffers. In the SPMD adaptation a channel is a
pure function over per-shard arrays that internally performs axis-name
collectives; the ``ChannelContext`` carries the axis name and accumulates
the per-channel traffic statistics (logical bytes / message counts that
cross worker boundaries — the quantity the paper's tables report).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ChannelContext:
    axis: str
    num_workers: int
    n_loc: int
    stats_bytes: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    stats_msgs: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)

    def me(self):
        return jax.lax.axis_index(self.axis)

    def add_traffic(self, name: str, nbytes, nmsgs):
        z = jnp.asarray(0, jnp.int64) if False else jnp.asarray(0, jnp.int32)
        self.stats_bytes[name] = self.stats_bytes.get(name, z) + jnp.asarray(
            nbytes, jnp.int32
        )
        self.stats_msgs[name] = self.stats_msgs.get(name, z) + jnp.asarray(
            nmsgs, jnp.int32
        )

    def stats(self) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
        return dict(self.stats_bytes), dict(self.stats_msgs)


def itemsize_of(x) -> int:
    return jnp.dtype(x.dtype).itemsize


def payload_width(payload) -> int:
    """Total bytes per message for a pytree payload (per leading element)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(payload):
        per = 1
        for d in leaf.shape[1:]:
            per *= d
        total += per * jnp.dtype(leaf.dtype).itemsize
    return total
