"""Channel base machinery: per-step context, registry, message accounting
(paper §IV — the channel interface every §IV-C optimization implements).

The paper's ``Channel`` base class exposes serialize()/deserialize() hooks
around raw per-peer byte buffers. In the SPMD adaptation a channel is a
pure function over per-shard arrays that internally performs axis-name
collectives; the ``ChannelContext`` carries the axis name and accumulates
the per-channel traffic statistics (logical bytes / message counts that
cross worker boundaries — the quantity the paper's tables report).

Two accounting regimes share the same ``add_traffic`` call sites:

  - *open* (no registry): stats keys appear dynamically as channels are
    traced — what a host-driven loop can consume, since the dict is
    rebuilt from scratch every superstep.
  - *registered*: a ``ChannelRegistry`` fixes the key set and per-key
    shape/dtype up front, so the accumulated stats form a fixed-shape
    pytree that can live in a ``lax.while_loop`` / ``lax.scan`` carry.
    Registries are discovered by a one-time dry trace of the step
    function (``jax.eval_shape`` — no compute), or declared explicitly.

Per-step counters are ``TRAFFIC_DTYPE`` (int32) on device. Host and
chunked modes accumulate across supersteps host-side in Python ints
(int64-safe); fused mode accumulates on device in int32 and latches a
wrap-detection flag that the runtime surfaces as a RuntimeWarning —
switch to chunked mode for runs heavy enough to trip it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

# Device-side traffic-counter dtype. Kept 32-bit: collectives and loop
# carries stay cheap, and cross-superstep totals are accumulated host-side
# in Python ints (arbitrary precision) at chunk boundaries.
TRAFFIC_DTYPE = jnp.int32


def key_under(key: str, prefix: str) -> bool:
    """Whether a "/"-namespaced stat key belongs to ``prefix`` (exact
    match or nested below it) — the single definition of the namespace
    convention used by registry/RunResult/compose prefix views."""
    return key == prefix or key.startswith(prefix + "/")


@dataclasses.dataclass(frozen=True)
class ChannelRegistry:
    """Fixed set of channel stat keys (and their per-shard shapes/dtypes).

    ``names`` is the ordered tuple of channel names that appear in one
    superstep; ``shapes``/``dtypes`` describe the per-step stat leaf for
    each name as produced by the *mapped* step function (e.g. ``(W,)``
    under vmap, ``()`` under shard_map).
    """

    names: Tuple[str, ...]
    shapes: Dict[str, tuple]
    dtypes: Dict[str, jnp.dtype]

    def zeros(self) -> Dict[str, jax.Array]:
        """One zeroed stats dict (used for both bytes and msgs accums)."""
        return {
            n: jnp.zeros(self.shapes[n], self.dtypes[n]) for n in self.names
        }

    def flags(self) -> Dict[str, jax.Array]:
        """A zeroed per-channel bool dict with the stat leaf shapes — the
        carry seed for the per-channel overflow latches."""
        return {n: jnp.zeros(self.shapes[n], bool) for n in self.names}

    @classmethod
    def from_stats_structure(cls, nbytes_struct) -> "ChannelRegistry":
        """Build from the (eval_shape'd) per-step bytes-stats dict."""
        names = tuple(sorted(nbytes_struct))
        return cls(
            names=names,
            shapes={n: tuple(nbytes_struct[n].shape) for n in names},
            dtypes={n: jnp.dtype(nbytes_struct[n].dtype) for n in names},
        )

    @classmethod
    def declare(cls, names, shape=(), dtype=TRAFFIC_DTYPE) -> "ChannelRegistry":
        """Explicit declaration (skips the dry trace)."""
        names = tuple(names)
        return cls(
            names=names,
            shapes={n: tuple(shape) for n in names},
            dtypes={n: jnp.dtype(dtype) for n in names},
        )

    # -- namespaced keys (composition layer, repro.core.compose) ----------
    #
    # Composed channels account traffic under "/"-separated names like
    # "sv/pointer/request"; the registry treats these as ordinary opaque
    # keys (the fused carry doesn't care), and offers prefix views so a
    # run's stats can be attributed per composed component.

    def under(self, prefix: str) -> Tuple[str, ...]:
        """Registered names belonging to ``prefix`` (exact or nested)."""
        return tuple(n for n in self.names if key_under(n, prefix))

    def prefixes(self) -> Tuple[str, ...]:
        """Distinct top-level namespaces across the registered names."""
        return tuple(sorted({n.split("/", 1)[0] for n in self.names}))


@dataclasses.dataclass
class ChannelContext:
    axis: str
    num_workers: int
    n_loc: int
    registry: ChannelRegistry = None
    stats_bytes: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    stats_msgs: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    # per-channel overflow latches (bool), same key set as the traffic
    # stats — the attribution the escalation/quarantine machinery consumes
    stats_ovf: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    # capacity-scale overrides keyed by full namespaced channel name (or
    # the "*" wildcard) — the engine's cap-escalation lever. Scales are
    # applied at trace time by scale_capacity(); 1.0 entries are dropped
    # by the engine so the default compile stays byte-identical.
    cap_scales: Dict[str, float] = dataclasses.field(default_factory=dict)
    # namespace prefix composed by the composition layer's child contexts,
    # so scale_capacity sees the same full names the registry records
    name_prefix: str = ""
    # names that actually reached add_traffic (a host-side trace-time
    # record — the runtime uses it to reject declared-but-never-traced
    # channels without a dedicated dry trace)
    touched: set = dataclasses.field(default_factory=set)
    # Batched query plane (repro.pregel.runtime, num_queries=Q). The step
    # function runs once per query lane under an inner vmap; these are the
    # per-lane scalars the routed channels use to escape that vmap and
    # share one union-frontier route pass across lanes (see
    # ``repro.core.routing.route_union``). All None on unbatched compiles.
    query_index: jax.Array = None   # () int32 lane id — batched over Q
    query_live: jax.Array = None    # () bool — lane's pre-step halt vote
    num_queries: int = None
    # partition-derived per-peer capacity bound for edge-derived routed
    # sends (PartitionedGraph.route_cap, threaded in by the runtime;
    # 0 = unknown). See edge_capacity().
    route_cap: int = 0

    def __post_init__(self):
        if self.registry is not None:
            # Seed every registered key so the stats structure is fixed
            # even when a channel is conditionally skipped this step.
            z = jnp.asarray(0, TRAFFIC_DTYPE)
            f = jnp.asarray(False)
            for n in self.registry.names:
                self.stats_bytes.setdefault(n, z)
                self.stats_msgs.setdefault(n, z)
                self.stats_ovf.setdefault(n, f)

    def me(self):
        return jax.lax.axis_index(self.axis)

    @property
    def batched(self) -> bool:
        """True when this step runs under the batched query plane."""
        return self.query_index is not None

    def add_traffic(self, name: str, nbytes, nmsgs):
        self.touched.add(name)
        if self.registry is not None and name not in self.registry.names:
            raise KeyError(
                f"channel {name!r} is not in the registry {self.registry.names} "
                "— it did not appear in the dry trace / declaration. Channels "
                "must be traced unconditionally (mask traffic to zero instead "
                "of skipping the call)."
            )
        z = jnp.asarray(0, TRAFFIC_DTYPE)
        self.stats_bytes[name] = self.stats_bytes.get(name, z) + jnp.asarray(
            nbytes, TRAFFIC_DTYPE
        )
        self.stats_msgs[name] = self.stats_msgs.get(name, z) + jnp.asarray(
            nmsgs, TRAFFIC_DTYPE
        )

    def add_overflow(self, name: str, flag):
        """Latch a channel's overflow flag under its stat key. Called by
        every routed channel right next to its add_traffic — same name,
        so the registry key-set validation in add_traffic covers it."""
        prev = self.stats_ovf.get(name, jnp.asarray(False))
        self.stats_ovf[name] = jnp.logical_or(prev, jnp.asarray(flag, bool))

    def edge_capacity(self, default: int) -> int:
        """Per-peer slot capacity for a routed send whose destinations are
        **graph edge endpoints** and that dedups before routing
        (CombinedMessage / RequestRespond over edge frontiers): the
        partition layer's ``route_cap`` — the max over (sender, owner)
        pairs of unique edge destinations — provably bounds any such
        frontier's per-owner occupancy, so the per-owner ``all_to_all``
        buffers shrink from the full-width ``default`` (= n_loc) to the
        partition-derived bound with zero overflow risk. Do NOT use it
        for pointer/state-derived destinations (e.g. pointer jumping)
        or non-deduping DirectMessage sends — those can exceed it.

        Falls back to ``default`` when no bound was threaded in, and
        never exceeds it (the bound is pow2-bucketed and may round past
        n_loc on small graphs)."""
        return min(self.route_cap, default) if self.route_cap else default

    def full_name(self, name: str) -> str:
        """``name`` qualified by the composition-layer namespace prefix —
        the key the registry (and the escalation machinery) sees."""
        return f"{self.name_prefix}/{name}" if self.name_prefix else name

    def scale_capacity(self, name: str, capacity: int) -> int:
        """Apply the engine's capacity-scale override for this channel
        (full name beats the "*" wildcard; absent/1.0 leaves the trace
        unchanged). Scaled caps re-bucket to the next power of two so the
        escalated executable lands on the pow2 compile-cache grid."""
        scale = self.cap_scales.get(
            self.full_name(name), self.cap_scales.get("*", 1.0))
        if not self.cap_scales or scale == 1.0:
            return capacity
        scaled = max(1, int(capacity * scale))
        return 1 << (scaled - 1).bit_length()

    def stats(self) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
        return dict(self.stats_bytes), dict(self.stats_msgs)


def itemsize_of(x) -> int:
    return jnp.dtype(x.dtype).itemsize


def payload_width(payload) -> int:
    """Total bytes per message for a pytree payload (per leading element)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(payload):
        per = 1
        for d in leaf.shape[1:]:
            per *= d
        total += per * jnp.dtype(leaf.dtype).itemsize
    return total
