"""Channel base machinery: per-step context, registry, message accounting
(paper §IV — the channel interface every §IV-C optimization implements).

The paper's ``Channel`` base class exposes serialize()/deserialize() hooks
around raw per-peer byte buffers. In the SPMD adaptation a channel is a
pure function over per-shard arrays that internally performs axis-name
collectives; the ``ChannelContext`` carries the axis name and accumulates
the per-channel traffic statistics (logical bytes / message counts that
cross worker boundaries — the quantity the paper's tables report).

Two accounting regimes share the same ``add_traffic`` call sites:

  - *open* (no registry): stats keys appear dynamically as channels are
    traced — what a host-driven loop can consume, since the dict is
    rebuilt from scratch every superstep.
  - *registered*: a ``ChannelRegistry`` fixes the key set and per-key
    shape/dtype up front, so the accumulated stats form a fixed-shape
    pytree that can live in a ``lax.while_loop`` / ``lax.scan`` carry.
    Registries are discovered by a one-time dry trace of the step
    function (``jax.eval_shape`` — no compute), or declared explicitly.

Per-step counters are ``TRAFFIC_DTYPE`` (int32) on device. Host and
chunked modes accumulate across supersteps host-side in Python ints
(int64-safe); fused mode accumulates on device in int32 and latches a
wrap-detection flag that the runtime surfaces as a RuntimeWarning —
switch to chunked mode for runs heavy enough to trip it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

# Device-side traffic-counter dtype. Kept 32-bit: collectives and loop
# carries stay cheap, and cross-superstep totals are accumulated host-side
# in Python ints (arbitrary precision) at chunk boundaries.
TRAFFIC_DTYPE = jnp.int32


def key_under(key: str, prefix: str) -> bool:
    """Whether a "/"-namespaced stat key belongs to ``prefix`` (exact
    match or nested below it) — the single definition of the namespace
    convention used by registry/RunResult/compose prefix views."""
    return key == prefix or key.startswith(prefix + "/")


@dataclasses.dataclass(frozen=True)
class ChannelRegistry:
    """Fixed set of channel stat keys (and their per-shard shapes/dtypes).

    ``names`` is the ordered tuple of channel names that appear in one
    superstep; ``shapes``/``dtypes`` describe the per-step stat leaf for
    each name as produced by the *mapped* step function (e.g. ``(W,)``
    under vmap, ``()`` under shard_map).
    """

    names: Tuple[str, ...]
    shapes: Dict[str, tuple]
    dtypes: Dict[str, jnp.dtype]

    def zeros(self) -> Dict[str, jax.Array]:
        """One zeroed stats dict (used for both bytes and msgs accums)."""
        return {
            n: jnp.zeros(self.shapes[n], self.dtypes[n]) for n in self.names
        }

    @classmethod
    def from_stats_structure(cls, nbytes_struct) -> "ChannelRegistry":
        """Build from the (eval_shape'd) per-step bytes-stats dict."""
        names = tuple(sorted(nbytes_struct))
        return cls(
            names=names,
            shapes={n: tuple(nbytes_struct[n].shape) for n in names},
            dtypes={n: jnp.dtype(nbytes_struct[n].dtype) for n in names},
        )

    @classmethod
    def declare(cls, names, shape=(), dtype=TRAFFIC_DTYPE) -> "ChannelRegistry":
        """Explicit declaration (skips the dry trace)."""
        names = tuple(names)
        return cls(
            names=names,
            shapes={n: tuple(shape) for n in names},
            dtypes={n: jnp.dtype(dtype) for n in names},
        )

    # -- namespaced keys (composition layer, repro.core.compose) ----------
    #
    # Composed channels account traffic under "/"-separated names like
    # "sv/pointer/request"; the registry treats these as ordinary opaque
    # keys (the fused carry doesn't care), and offers prefix views so a
    # run's stats can be attributed per composed component.

    def under(self, prefix: str) -> Tuple[str, ...]:
        """Registered names belonging to ``prefix`` (exact or nested)."""
        return tuple(n for n in self.names if key_under(n, prefix))

    def prefixes(self) -> Tuple[str, ...]:
        """Distinct top-level namespaces across the registered names."""
        return tuple(sorted({n.split("/", 1)[0] for n in self.names}))


@dataclasses.dataclass
class ChannelContext:
    axis: str
    num_workers: int
    n_loc: int
    registry: ChannelRegistry = None
    stats_bytes: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    stats_msgs: Dict[str, jax.Array] = dataclasses.field(default_factory=dict)
    # names that actually reached add_traffic (a host-side trace-time
    # record — the runtime uses it to reject declared-but-never-traced
    # channels without a dedicated dry trace)
    touched: set = dataclasses.field(default_factory=set)
    # Batched query plane (repro.pregel.runtime, num_queries=Q). The step
    # function runs once per query lane under an inner vmap; these are the
    # per-lane scalars the routed channels use to escape that vmap and
    # share one union-frontier route pass across lanes (see
    # ``repro.core.routing.route_union``). All None on unbatched compiles.
    query_index: jax.Array = None   # () int32 lane id — batched over Q
    query_live: jax.Array = None    # () bool — lane's pre-step halt vote
    num_queries: int = None

    def __post_init__(self):
        if self.registry is not None:
            # Seed every registered key so the stats structure is fixed
            # even when a channel is conditionally skipped this step.
            z = jnp.asarray(0, TRAFFIC_DTYPE)
            for n in self.registry.names:
                self.stats_bytes.setdefault(n, z)
                self.stats_msgs.setdefault(n, z)

    def me(self):
        return jax.lax.axis_index(self.axis)

    @property
    def batched(self) -> bool:
        """True when this step runs under the batched query plane."""
        return self.query_index is not None

    def add_traffic(self, name: str, nbytes, nmsgs):
        self.touched.add(name)
        if self.registry is not None and name not in self.registry.names:
            raise KeyError(
                f"channel {name!r} is not in the registry {self.registry.names} "
                "— it did not appear in the dry trace / declaration. Channels "
                "must be traced unconditionally (mask traffic to zero instead "
                "of skipping the call)."
            )
        z = jnp.asarray(0, TRAFFIC_DTYPE)
        self.stats_bytes[name] = self.stats_bytes.get(name, z) + jnp.asarray(
            nbytes, TRAFFIC_DTYPE
        )
        self.stats_msgs[name] = self.stats_msgs.get(name, z) + jnp.asarray(
            nmsgs, TRAFFIC_DTYPE
        )

    def stats(self) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
        return dict(self.stats_bytes), dict(self.stats_msgs)


def itemsize_of(x) -> int:
    return jnp.dtype(x.dtype).itemsize


def payload_width(payload) -> int:
    """Total bytes per message for a pytree payload (per leading element)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(payload):
        per = 1
        for d in leaf.shape[1:]:
            per *= d
        total += per * jnp.dtype(leaf.dtype).itemsize
    return total
