"""Channel composition layer (paper §V).

The paper's central claim is that optimizations become *composable* once
they are expressed as channels: the S-V case study (§V, Table VI) stacks
the request-respond, scatter-combine and combiner optimizations to beat
the best prior implementation by 2.20x. This module is the layer that
makes such stacks first-class objects instead of ad-hoc step-function
code:

  - ``Stacked`` — a named bundle of channel components. Every component's
    traffic is accounted under a *namespaced* stat key
    (``<stack>/<component>[/<sub>]``), so a composed run attributes bytes
    and messages to each constituent optimization, and the whole stack
    contributes one predeclarable ``ChannelRegistry`` entry set
    (``channel_names()`` plugs straight into ``run_supersteps(channels=)``).
  - ``fused_exchange`` — merges several *independent* planned exchanges
    into one collective round: all send buffers of one dtype share a
    single tiled ``all_to_all`` instead of one collective per channel.
  - ``switch_by_density`` — runs two channel implementations of the same
    logical exchange (a dense broadcast and a sparse push, say) and
    selects by a worker-uniform density threshold. Under the static-shape
    SPMD tracing model both branches are traced and executed every
    superstep (the registry contract requires channels to be traced
    unconditionally); the selector decides which *result* is used and
    which branch's *traffic* is accounted — consistent with how this
    library counts logical messages everywhere (see ``propagation``).
    ``density_adaptive_combine`` is the canonical instance: the same
    logical neighborhood combine as a *planned* positional
    scatter-combine (dense frontiers) vs a *routed* compact
    combined-message push (sparse frontiers), decided per superstep by
    live frontier density from the loop carry.

Composition never changes a channel's semantics: every combinator is a
pure function over the same per-shard arrays, so composed programs run
unchanged under the ``host``, ``fused`` and ``chunked`` execution modes.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs import knobs
from repro.core.channel import TRAFFIC_DTYPE, ChannelContext, key_under

#: the density-switch threshold knob (explicit > dense_threshold_scope >
#: REPRO_DENSE_THRESHOLD > 0.1): the frontier fraction at or above which
#: :func:`density_adaptive_combine` takes the planned dense broadcast.
#: ``Engine`` threads its planner-chosen threshold through the scope at
#: compile time, exactly like the use_kernel/route knobs.
DENSE_THRESHOLD = knobs.Knob(
    "dense_threshold", env="REPRO_DENSE_THRESHOLD", default=0.1,
    parse=float, coerce=float)


def resolve_dense_threshold(threshold: Optional[float] = None) -> float:
    """The density-switch threshold for a call site (explicit > scope >
    env > 0.1 — see ``repro.configs.knobs``)."""
    return DENSE_THRESHOLD.resolve(threshold)


def dense_threshold_scope(threshold: Optional[float]):
    """Pin the density-switch threshold for every adaptive combine under
    the scope (trace-time: wrap the compile, not the execution)."""
    return DENSE_THRESHOLD.scope(threshold)


# ---------------------------------------------------------------------------
# scoped accounting: child contexts whose stats fold back, namespaced
# ---------------------------------------------------------------------------


def child_context(ctx: ChannelContext, prefix: str = "") -> ChannelContext:
    """An *open* child context (no registry) sharing ctx's topology.

    Channels called with the child accumulate stats locally; fold them
    into the parent with :func:`merge_child`. Used wherever a combinator
    needs to rename or mask a component's traffic before it reaches the
    parent's (possibly registered, fixed-key) accounting.

    ``prefix`` (the name the child's stats will be merged under) composes
    the namespace so cap-scale lookups inside the child resolve the same
    full channel names the parent registry records; the engine's
    ``cap_scales`` ride along.
    """
    sub = ChannelContext(ctx.axis, ctx.num_workers, ctx.n_loc)
    sub.cap_scales = ctx.cap_scales
    sub.route_cap = ctx.route_cap
    sub.name_prefix = ctx.full_name(prefix) if prefix else ctx.name_prefix
    return sub


def merge_child(
    ctx: ChannelContext,
    child: ChannelContext,
    prefix: str = "",
    select=None,
) -> None:
    """Fold a child's stats into ``ctx`` under ``prefix/<key>``.

    select: optional 0/1 scalar (traced OK) multiplied into every counter
    — how :func:`switch_by_density` accounts only the chosen branch.
    """
    sel = None if select is None else jnp.asarray(select, TRAFFIC_DTYPE)
    for key in child.stats_bytes:
        name = f"{prefix}/{key}" if prefix else key
        nb, nm = child.stats_bytes[key], child.stats_msgs[key]
        if sel is not None:
            nb, nm = nb * sel, nm * sel
        ctx.add_traffic(name, nb, nm)
    for key in child.stats_ovf:
        name = f"{prefix}/{key}" if prefix else key
        ovf = child.stats_ovf[key]
        if sel is not None:
            # the unselected branch of a density switch must not latch
            ovf = jnp.logical_and(ovf, sel != 0)
        ctx.add_overflow(name, ovf)


@contextlib.contextmanager
def scoped(ctx: ChannelContext, prefix: str, select=None):
    """``with scoped(ctx, "sv/jump") as sub:`` — namespaced accounting."""
    sub = child_context(ctx, prefix)
    yield sub
    merge_child(ctx, sub, prefix, select)


# ---------------------------------------------------------------------------
# Stacked: a named, declarable bundle of channel components
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Component:
    """One constituent channel of a :class:`Stacked` composition.

    fn: ``fn(ctx, name, *args, **kw)`` — a closure over a channel call
      that forwards ``name`` as the channel's stat-key name.
    stats: the stat-key *suffixes* the channel contributes under its name
      — ``()`` for single-key channels (the bare name), or e.g.
      ``("request", "respond")`` for the request-respond channel.
    """

    fn: Callable
    stats: Tuple[str, ...] = ()

    def names_under(self, name: str) -> Tuple[str, ...]:
        if not self.stats:
            return (name,)
        return tuple(f"{name}/{s}" for s in self.stats)


class Stacked:
    """A composition of channels with per-component traffic attribution.

    Calling ``stack.call(ctx, key, *args)`` invokes component ``key`` with
    the namespaced stat name ``<stack.name>/<key>``; all components
    together form one fixed registry entry set (``channel_names()``),
    which ``run_supersteps(channels=stack)`` validates against the dry
    trace. This is the object the paper's §V case study builds for S-V.
    """

    def __init__(self, name: str, components: Dict[str, Component]):
        self.name = name
        self.components = dict(components)

    def call(self, ctx: ChannelContext, key: str, *args, **kw):
        comp = self.components[key]
        return comp.fn(ctx, f"{self.name}/{key}", *args, **kw)

    __call__ = call

    def channel_names(self) -> Tuple[str, ...]:
        names: List[str] = []
        for key, comp in self.components.items():
            names.extend(comp.names_under(f"{self.name}/{key}"))
        return tuple(sorted(names))


def stacked(name: str, **components: Component) -> Stacked:
    """Sugar: ``stacked("sv", pointer=Component(...), ...)``."""
    return Stacked(name, components)


def request_component() -> Component:
    """The request-respond channel as a stack component: args
    ``(dst, valid, vals, capacity)``, stats ``request``/``respond``."""

    def fn(ctx, name, dst, valid, vals, capacity):
        from repro.core import request_respond as rr

        return rr.request(ctx, dst, valid, vals, capacity=capacity,
                          name=name)

    return Component(fn, stats=("request", "respond"))


def combined_component(combiner) -> Component:
    """A CombinedMessage send as a stack component: args
    ``(dst, valid, vals, capacity)``."""

    def fn(ctx, name, dst, valid, vals, capacity):
        from repro.core import message as msg

        return msg.combined_send(ctx, dst, valid, vals, combiner,
                                 capacity=capacity, name=name)

    return Component(fn)


def channel_names_of(channels) -> Tuple[str, ...]:
    """Normalize a ``channels=`` declaration: a single name, a composed
    channel (anything with ``channel_names()``), or a mixed sequence."""
    if isinstance(channels, str):
        return (channels,)
    if hasattr(channels, "channel_names"):
        return tuple(channels.channel_names())
    names: List[str] = []
    for c in channels:
        if hasattr(c, "channel_names"):
            names.extend(c.channel_names())
        else:
            names.append(c)
    return tuple(names)


# ---------------------------------------------------------------------------
# fused_exchange: several independent exchanges, one collective round
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PlannedExchange:
    """A channel exchange split at the collective boundary.

    ``payload`` holds the ready-to-send buffers — a pytree of
    ``(W, C, ...)`` arrays where row ``p`` is the block destined to peer
    ``p`` (the shape every channel in this library packs to).
    ``finish(recv)`` consumes the identically-shaped received pytree and
    produces the channel's result. ``nbytes``/``nmsgs`` is the remote
    traffic this exchange accounts under ``name``.
    """

    name: str
    payload: Any
    finish: Callable[[Any], Any]
    nbytes: Any
    nmsgs: Any


def fused_exchange(ctx: ChannelContext, parts: Sequence[PlannedExchange]) -> list:
    """Execute several planned exchanges in one collective round.

    All send buffers of equal dtype are flattened to ``(W, -1)``,
    concatenated, and exchanged with a *single* tiled ``all_to_all``
    (one collective per distinct dtype instead of one per channel); the
    received block is split back and each part's ``finish`` runs on its
    own slice. Results come back in ``parts`` order. Each part's traffic
    is accounted under its own name — fusing the wire round never blurs
    the per-channel attribution.

    The parts must be data-independent (none may consume another's
    result) — the same condition under which the paper may merge channel
    exchanges into one message round.
    """
    if not parts:
        return []
    flat_parts = []
    for part in parts:
        leaves, treedef = jax.tree_util.tree_flatten(part.payload)
        flat_parts.append((leaves, treedef))

    # group leaves across parts by dtype: one collective per dtype
    groups: Dict[Any, List[Tuple[int, int, jax.Array]]] = {}
    for pi, (leaves, _) in enumerate(flat_parts):
        for li, leaf in enumerate(leaves):
            groups.setdefault(jnp.dtype(leaf.dtype), []).append((pi, li, leaf))

    recv_leaves: List[List[Optional[jax.Array]]] = [
        [None] * len(leaves) for leaves, _ in flat_parts
    ]
    for items in groups.values():
        w = items[0][2].shape[0]
        cols = [leaf.reshape(w, -1) for _, _, leaf in items]
        widths = [col.shape[1] for col in cols]
        merged = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
        back = jax.lax.all_to_all(merged, ctx.axis, 0, 0, tiled=True)
        off = 0
        for (pi, li, leaf), width in zip(items, widths):
            recv_leaves[pi][li] = back[:, off : off + width].reshape(leaf.shape)
            off += width

    results = []
    for pi, part in enumerate(parts):
        ctx.add_traffic(part.name, part.nbytes, part.nmsgs)
        recv = jax.tree_util.tree_unflatten(flat_parts[pi][1], recv_leaves[pi])
        results.append(part.finish(recv))
    return results


# ---------------------------------------------------------------------------
# switch_by_density: density-directed choice between two channel impls
# ---------------------------------------------------------------------------


def global_fraction(ctx: ChannelContext, local_count, local_total) -> jax.Array:
    """Worker-uniform fraction ``sum(count) / sum(total)`` (f32 scalar)."""
    num = jax.lax.psum(jnp.asarray(local_count, jnp.float32), ctx.axis)
    den = jax.lax.psum(jnp.asarray(local_total, jnp.float32), ctx.axis)
    return num / jnp.maximum(den, 1.0)


def switch_by_density(
    ctx: ChannelContext,
    name: str,
    density,
    threshold: Optional[float],
    dense_fn: Callable[[ChannelContext], Any],
    sparse_fn: Callable[[ChannelContext], Any],
):
    """Select between two implementations of one logical exchange.

    ``dense_fn(sub_ctx)`` and ``sparse_fn(sub_ctx)`` must return results
    of identical pytree structure; ``density`` must be worker-uniform
    (use :func:`global_fraction`). Returns ``(result, use_dense)`` where
    ``result`` is the dense result when ``density >= threshold`` and the
    sparse one otherwise.

    Both branches are traced and executed unconditionally (the registry
    contract — and ``lax.cond`` branches could not mutate the trace-time
    stats dict anyway); only the chosen branch's traffic is accounted,
    under ``<name>/dense/...`` and ``<name>/sparse/...``, mirroring the
    logical-message accounting used throughout this library.

    ``threshold=None`` resolves through the :data:`DENSE_THRESHOLD` knob
    at trace time (scope > env > 0.1) — the planner's entry point.
    """
    use_dense = jnp.asarray(density) >= resolve_dense_threshold(threshold)
    d_ctx = child_context(ctx, f"{name}/dense")
    s_ctx = child_context(ctx, f"{name}/sparse")
    d_out = dense_fn(d_ctx)
    s_out = sparse_fn(s_ctx)
    sel = use_dense.astype(TRAFFIC_DTYPE)
    merge_child(ctx, d_ctx, f"{name}/dense", select=sel)
    merge_child(ctx, s_ctx, f"{name}/sparse", select=1 - sel)
    result = jax.tree_util.tree_map(
        lambda a, b: jnp.where(use_dense, a, b), d_out, s_out
    )
    return result, use_dense


def density_adaptive_combine(
    ctx: ChannelContext,
    name: str,
    density,
    threshold: Optional[float],
    *,
    plan,
    dense_vals: jax.Array,
    dst: jax.Array,
    valid: jax.Array,
    sparse_vals: jax.Array,
    combiner,
    capacity: int,
    use_kernel=None,
    edge_transform=None,
):
    """Routed-vs-planned exchange for one logical neighborhood combine,
    selected by live frontier density.

    The two implementations of the same logical exchange are the two ends
    of the data plane: the *planned* positional ScatterCombine broadcast
    (``plan`` + ``dense_vals`` — static routing, no ids on the wire, cost
    independent of the frontier) and the *routed* CombinedMessage push
    (``dst``/``valid``/``sparse_vals`` — one-pass bucket routing, ids on
    the wire but only active messages travel). ``density`` must be
    worker-uniform and should come from the loop carry (e.g.
    ``global_fraction(ctx, active & v_mask, v_mask)``) — the decision
    tracks the frontier *live*, per superstep, inside the fused loop.

    Returns ``(combined (n_loc,[D]) — combiner identity where nothing
    arrived, overflow, use_dense)``; traffic lands under
    ``<name>/dense/scatter_combine`` vs ``<name>/sparse/combined_message``.
    """

    def dense(sub):
        from repro.core import scatter_combine as sc

        out = sc.broadcast_combine(
            sub, plan, dense_vals, combiner,
            edge_transform=edge_transform, use_kernel=use_kernel,
        )
        return out, jnp.asarray(False)

    def sparse(sub):
        from repro.core import message as msg

        out, _, ovf = msg.combined_send(
            sub, dst, valid, sparse_vals, combiner, capacity=capacity,
            use_kernel=use_kernel,
        )
        return out, ovf

    (result, overflow), use_dense = switch_by_density(
        ctx, name, density, threshold, dense, sparse
    )
    return result, overflow, use_dense


# ---------------------------------------------------------------------------
# stat helpers for namespaced keys
# ---------------------------------------------------------------------------


def group_stats(stats: Dict[str, int]) -> Dict[str, int]:
    """Collapse namespaced stats to per-top-level-prefix totals."""
    out: Dict[str, int] = {}
    for key, val in stats.items():
        top = key.split("/", 1)[0]
        out[top] = out.get(top, 0) + val
    return out


def stats_under(stats: Dict[str, int], prefix: str) -> Dict[str, int]:
    """The subset of ``stats`` belonging to ``prefix`` (exact or nested)."""
    return {k: v for k, v in stats.items() if key_under(k, prefix)}
