"""Generic segmented reduction for custom (non-lattice) combiners —
supports the sender/receiver-side combines of the paper's §IV-C1
scatter-combine channel and the heterogeneous combiners of Table IV.

``jax.ops.segment_*`` covers sum/min/max; channels also allow arbitrary
associative+commutative combiners (e.g. min-by-key with payload, used by
Boruvka MSF, paper Table IV). This implements the same segmented
Hillis-Steele scan the Pallas kernel uses, in pure jnp, over sorted
segment ids.

Shape-static by construction (the scan ladder depends only on M), so it
is safe inside the fused runtime's ``lax.while_loop``/``lax.scan`` body.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segmented_reduce_sorted(vals, seg, num_segments, combine_fn, ident_of):
    """Reduce `vals` within runs of equal (sorted) `seg`.

    Args:
      vals: pytree of (M, ...) arrays.
      seg: (M,) int32 sorted segment ids; ids >= num_segments are dropped.
      combine_fn: pytree-wise binary combiner (applied leaf-wise via tree_map
        if given a pair of pytrees; here we apply to the whole pytree).
      ident_of: callable leaf -> identity array of same shape/dtype.
    Returns:
      pytree of (num_segments, ...) reduced values (identity if empty).
    """
    m = seg.shape[0]
    leaves, treedef = jax.tree_util.tree_flatten(vals)

    def scan_step(vs, shift):
        prev_s = jnp.concatenate([jnp.full((shift,), -1, seg.dtype), seg[:-shift]])
        same = prev_s == seg
        shifted = [
            jnp.concatenate([ident_of(v)[:shift], v[:-shift]], axis=0) for v in vs
        ]
        a = jax.tree_util.tree_unflatten(treedef, vs)
        b = jax.tree_util.tree_unflatten(treedef, shifted)
        combined = combine_fn(a, b)
        cl = jax.tree_util.tree_leaves(combined)
        out = []
        for v, c in zip(vs, cl):
            mask = same.reshape((m,) + (1,) * (v.ndim - 1))
            out.append(jnp.where(mask, c, v))
        return out

    shift = 1
    while shift < m:
        leaves = scan_step(leaves, shift)
        shift *= 2

    # last position of each segment
    last = jnp.searchsorted(
        seg, jnp.arange(num_segments, dtype=seg.dtype), side="right"
    ) - 1
    first = jnp.searchsorted(
        seg, jnp.arange(num_segments, dtype=seg.dtype), side="left"
    )
    nonempty = last >= first

    def pick(v):
        got = v[jnp.clip(last, 0, m - 1)]
        idn = ident_of(v)[:1]
        mask = nonempty.reshape((num_segments,) + (1,) * (v.ndim - 1))
        return jnp.where(mask, got, jnp.broadcast_to(idn, got.shape))

    return jax.tree_util.tree_unflatten(treedef, [pick(v) for v in leaves])
