"""Standard message-passing channels (paper Table I).

DirectMessage — arbitrary (dst, payload) messages; the receiver iterates
over deliveries. CombinedMessage — a combiner is applied both sender-side
(per destination, before the exchange) and receiver-side, yielding a dense
per-vertex combined value. Both use dynamic sort-based routing, and both
put destination ids on the wire — the costs the optimized channels remove.

Registry contract (fused runtime): every send is traced unconditionally —
an empty `valid` mask yields zero accounted traffic rather than a skipped
``add_traffic`` call, so the per-step stats pytree keeps a fixed shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import combiners as cb
from repro.core import routing
from repro.core.channel import ChannelContext, payload_width
from repro.kernels import ops as kops


@dataclasses.dataclass
class Delivery:
    """Messages delivered to this worker (flattened over peers)."""

    dst_local: jax.Array   # (K,) int32 local destination index (n_loc pad)
    payload: Any           # pytree of (K, ...) arrays
    mask: jax.Array        # (K,) bool
    overflow: jax.Array    # () bool


def direct_send(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    payload,
    capacity: int,
    *,
    name: str = "direct_message",
    id_bytes: int = 4,
    wire_width: int = None,
) -> Delivery:
    """DirectMessage: deliver (dst, payload) messages to dst's owner.

    wire_width overrides the accounted per-message payload width (used by
    the monolithic-Pregel emulation where every message is padded to the
    program-wide maximum message type)."""
    routed = routing.route(ctx, dst, valid, payload, capacity)
    remote = routing.remote_count(ctx, routed.sent_count)
    width = id_bytes + (wire_width if wire_width is not None
                        else payload_width(payload))
    ctx.add_traffic(name, remote * width, remote)
    w, c = ctx.num_workers, capacity
    flat = jax.tree_util.tree_map(
        lambda x: x.reshape((w * c,) + x.shape[2:]), routed.payload
    )
    ids = routed.ids.reshape(-1)
    dst_local = jnp.where(
        routed.mask.reshape(-1), ids - ctx.me() * ctx.n_loc, ctx.n_loc
    ).astype(jnp.int32)
    return Delivery(
        dst_local=dst_local,
        payload=flat,
        mask=routed.mask.reshape(-1),
        overflow=routed.overflow,
    )


def combined_send(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    vals: jax.Array,
    combiner,
    capacity: int,
    *,
    name: str = "combined_message",
    use_kernel: Optional[bool] = None,
    wire_width: int = None,
):
    """CombinedMessage: sender-side combine per destination, route, then
    receiver-side combine to a dense (n_loc, D) array.

    Returns (combined (n_loc,[D]), got_any (n_loc,) bool, overflow).
    """
    combiner = cb.get(combiner)
    squeeze = vals.ndim == 1
    v = vals[:, None] if squeeze else vals
    m, d = v.shape
    ident = combiner.ident_for(v.dtype)

    # sender-side combine: sort by dst, reduce runs, keep one entry per dst
    key = jnp.where(valid, dst.astype(jnp.int32), routing.BIG)
    order = jnp.argsort(key)
    sdst = key[order]
    sval = jnp.where((sdst != routing.BIG)[:, None], v[order], ident)
    prev = jnp.concatenate([jnp.full((1,), -1, sdst.dtype), sdst[:-1]])
    first = (sdst != prev) & (sdst != routing.BIG)
    run = jnp.cumsum(first.astype(jnp.int32)) - 1  # run id per sorted pos
    run = jnp.where(sdst != routing.BIG, run, m)
    combined = kops.segment_combine(
        sval, run, m, combiner, use_kernel=use_kernel, assume_sorted=True
    )  # (m, d) value per run id
    # unique dst per run id
    u_dst = jnp.full((m + 1,), routing.BIG, jnp.int32)
    u_dst = u_dst.at[jnp.where(first, run, m)].set(sdst, mode="drop")
    u_dst = u_dst[:m]

    routed = routing.route(
        ctx, u_dst, u_dst != routing.BIG, {"v": combined}, capacity
    )
    remote = routing.remote_count(ctx, routed.sent_count)
    width = 4 + (wire_width if wire_width is not None
                 else d * jnp.dtype(v.dtype).itemsize)
    ctx.add_traffic(name, remote * width, remote)

    w, c = ctx.num_workers, capacity
    flat_v = routed.payload["v"].reshape(w * c, d)
    ids = routed.ids.reshape(-1)
    dst_local = jnp.where(
        routed.mask.reshape(-1), ids - ctx.me() * ctx.n_loc, ctx.n_loc
    ).astype(jnp.int32)
    flat_v = jnp.where(routed.mask.reshape(-1)[:, None], flat_v, ident)
    out = kops.segment_combine(flat_v, dst_local, ctx.n_loc, combiner,
                               use_kernel=False)
    got = (
        jax.ops.segment_sum(
            routed.mask.reshape(-1).astype(jnp.int32), dst_local, ctx.n_loc
        )
        > 0
    )
    return (out[:, 0] if squeeze else out), got, routed.overflow


def monolithic_send(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    payload,
    capacity: int,
    *,
    pad_width: int,
    name: str = "pregel_message",
) -> Delivery:
    """Pregel-monolithic emulation (Table IV baseline): every message is
    padded to the program-wide maximum message width `pad_width`, and no
    per-channel combiner can be applied."""
    routed = routing.route(ctx, dst, valid, payload, capacity)
    remote = routing.remote_count(ctx, routed.sent_count)
    ctx.add_traffic(name, remote * (4 + pad_width), remote)
    w, c = ctx.num_workers, capacity
    flat = jax.tree_util.tree_map(
        lambda x: x.reshape((w * c,) + x.shape[2:]), routed.payload
    )
    ids = routed.ids.reshape(-1)
    dst_local = jnp.where(
        routed.mask.reshape(-1), ids - ctx.me() * ctx.n_loc, ctx.n_loc
    ).astype(jnp.int32)
    return Delivery(dst_local, flat, routed.mask.reshape(-1), routed.overflow)
