"""Standard message-passing channels (paper Table I).

DirectMessage — arbitrary (dst, payload) messages; the receiver iterates
over deliveries. CombinedMessage — a combiner is applied both sender-side
(per destination, before the exchange) and receiver-side, yielding a dense
per-vertex combined value. Both use the dynamic routed exchange
(``repro.core.routing``, one-pass bucket routing by default), and both
put destination ids on the wire — the costs the optimized channels remove.

The CombinedMessage sender-side combine is sort-free: the
unique-destination list is compacted with a counting prefix-sum
(``routing.dedup_dense``) and values are reduced directly in that
compact space — O(M·W + N) work with only an int32 histogram as the
N-sized transient, no ``argsort`` anywhere on the dynamic data plane
(non-lattice combiners such as ``min_by_first`` still sort inside their
``segment_reduce``). ``id_bytes`` are charged once per *wire* message
(the post-dedup, capacity-packed sends that actually cross a worker
boundary), never per enqueued send.

Registry contract (fused runtime): every send is traced unconditionally —
an empty `valid` mask yields zero accounted traffic rather than a skipped
``add_traffic`` call, so the per-step stats pytree keeps a fixed shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap

from repro.core import combiners as cb
from repro.core import routing
from repro.core.channel import ChannelContext, payload_width
from repro.kernels import ops as kops


@dataclasses.dataclass
class Delivery:
    """Messages delivered to this worker (flattened over peers)."""

    dst_local: jax.Array   # (K,) int32 local destination index (n_loc pad)
    payload: Any           # pytree of (K, ...) arrays
    mask: jax.Array        # (K,) bool
    overflow: jax.Array    # () bool


def _delivery(ctx: ChannelContext, routed: routing.Routed, capacity: int):
    """Flatten a Routed into per-message local-index delivery form."""
    w, c = ctx.num_workers, capacity
    flat = jax.tree_util.tree_map(
        lambda x: x.reshape((w * c,) + x.shape[2:]), routed.payload
    )
    ids = routed.ids.reshape(-1)
    dst_local = jnp.where(
        routed.mask.reshape(-1), ids - ctx.me() * ctx.n_loc, ctx.n_loc
    ).astype(jnp.int32)
    return Delivery(
        dst_local=dst_local,
        payload=flat,
        mask=routed.mask.reshape(-1),
        overflow=routed.overflow,
    )


def direct_send(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    payload,
    capacity: int,
    *,
    name: str = "direct_message",
    id_bytes: int = 4,
    wire_width: int = None,
) -> Delivery:
    """DirectMessage: deliver (dst, payload) messages to dst's owner.

    wire_width overrides the accounted per-message payload width (used by
    the monolithic-Pregel emulation where every message is padded to the
    program-wide maximum message type)."""
    capacity = ctx.scale_capacity(name, capacity)
    routed = _route_maybe_union(ctx, dst, valid, payload, capacity)
    remote = routing.remote_count(ctx, routed.sent_count)
    width = id_bytes + (wire_width if wire_width is not None
                        else payload_width(payload))
    ctx.add_traffic(name, remote * width, remote)
    ctx.add_overflow(name, routed.overflow)
    return _delivery(ctx, routed, capacity)


# combiners whose segment reductions are order-independent for any dtype
_UNION_EXACT_LATTICE = ("min", "max", "or")


def _union_exact(combiner, dtype) -> bool:
    """Whether the union-frontier batched path reproduces serial results
    bit for bit for this combiner: lattice ops are order-independent
    under the union's slot reordering; sum/prod only when the value dtype
    is exact (float reassociation would round differently)."""
    if combiner.name in _UNION_EXACT_LATTICE:
        return True
    return combiner.name in ("sum", "prod") and not jnp.issubdtype(
        jnp.dtype(dtype), jnp.inexact)


def _route_maybe_union(ctx, dst, valid, payload, capacity):
    """The routed-channel dispatch: the shared union-frontier pass under
    the batched query plane (``route_batch="union"``), the plain serial
    route otherwise (which the query vmap batches into Q passes)."""
    if getattr(ctx, "batched", False) and routing.resolve_batch() == "union":
        return routing.route_union(ctx, dst, valid, payload, capacity)
    return routing.route(ctx, dst, valid, payload, capacity)


def _combined_send_serial(ctx, dst, valid, v, combiner, capacity, use_kernel):
    """The serial CombinedMessage body (also the per-lane body the query
    vmap batches under ``route_batch="lane"``). Returns
    (out (n_loc, D), got (n_loc,), overflow (), remote ())."""
    m, d = v.shape
    n_total = ctx.num_workers * ctx.n_loc
    ident = combiner.ident_for(v.dtype)

    # sender-side combine, sort-free: compact the occupied destinations
    # into an ascending unique list (counting prefix-sum over the id
    # space), then reduce the values directly in that compact space —
    # the only O(N_global) transient is dedup_dense's int32 histogram;
    # values never materialize densely.
    u_dst, pos = routing.dedup_dense(dst, valid, n_total)
    u_valid = u_dst != routing.BIG
    seg = jnp.where(
        valid, pos[jnp.clip(dst.astype(jnp.int32), 0, n_total - 1)], m
    )
    u_vals = combiner.segment_reduce(v, seg, m)  # (m, d), u_dst-aligned

    routed = routing.route(
        ctx, u_dst, u_valid, {"v": u_vals}, capacity, use_kernel=use_kernel
    )
    remote = routing.remote_count(ctx, routed.sent_count)

    deliv = _delivery(ctx, routed, capacity)
    flat_v = jnp.where(deliv.mask[:, None], deliv.payload["v"], ident)
    out = kops.segment_combine(flat_v, deliv.dst_local, ctx.n_loc, combiner,
                               use_kernel=False)
    got = (
        jax.ops.segment_sum(
            deliv.mask.astype(jnp.int32), deliv.dst_local, ctx.n_loc
        )
        > 0
    )
    return out, got, routed.overflow, remote


def _combined_send_union(ctx, dst, valid, v, combiner, capacity, use_kernel):
    """CombinedMessage across Q query lanes with ONE dedup + route pass
    over the union frontier (see ``repro.core.routing.route_union`` for
    the mechanism and exactness contract). Per-lane combined values ride
    the wire as a (slots, Q·D) lane matrix; the combiner is applied per
    lane on both sides of the exchange.

    Per-lane results (out/got/remote) are bit-identical to the serial
    body whenever the union pass does not overflow and the combiner is
    union-exact (:func:`_union_exact`)."""
    W, n_loc, ax = ctx.num_workers, ctx.n_loc, ctx.axis
    n_total = W * n_loc
    m, d = v.shape
    c = capacity
    ident = combiner.ident_for(v.dtype)
    impl = routing.resolve_impl(None)

    @custom_vmap
    def ex(qidx, live, dst, valid, v):
        return _combined_send_serial(
            ctx, dst, valid & live, v, combiner, c, use_kernel)

    @ex.def_vmap
    def _rule(axis_size, in_batched, qidx, live, dst, valid, v):
        q = axis_size
        _, lb, db, vb, vvb = in_batched
        live2 = live if lb else jnp.broadcast_to(live, (q,))
        valid2 = valid if vb else jnp.broadcast_to(valid, (q, m))
        valid_eff = valid2 & live2[:, None]  # (Q, M)
        dst2 = (dst if db else jnp.broadcast_to(dst, (q, m))).astype(jnp.int32)
        v2 = v if vvb else jnp.broadcast_to(v, (q, m, d))

        # ---- union dedup over the id space (one histogram, all lanes) ----
        u_cap = min(q * m, n_total)
        u_dst, pos = routing.union_dedup(dst2, valid_eff, n_total, u_cap)
        u_valid = u_dst != routing.BIG
        # per-lane combine into the SHARED compact space; lane membership
        # marks which unique ids each lane actually sends
        seg_l = jnp.where(
            valid_eff, pos[jnp.clip(dst2, 0, n_total - 1)], u_cap)  # (Q, M)
        u_vals = jax.vmap(
            lambda vv, ss: combiner.segment_reduce(vv, ss, u_cap)
        )(v2, seg_l)  # (Q, u_cap, D)
        lane_has = (
            jnp.zeros((q, u_cap + 1), jnp.int32)
            .at[jnp.arange(q)[:, None], seg_l]
            .add(1)[:, :u_cap]
            > 0
        )  # (Q, u_cap)

        # ---- ONE bucket-route pass over the union unique list ----
        owner_u = jnp.clip(u_dst // n_loc, 0, W - 1)
        key_u = jnp.where(u_valid, owner_u, W).astype(jnp.int32)
        lanes = lane_has.T  # (u_cap, Q)
        rank, count, lane_counts = routing.union_ranks(
            key_u, lanes, W, impl=impl, use_kernel=use_kernel)
        fits = rank < c
        packed = u_valid & fits
        slot = jnp.where(packed, key_u * c + rank, W * c)
        ovf_l = jnp.any(lane_has & ~fits[None, :], axis=1)  # (Q,)
        sent_l = jnp.minimum(lane_counts, c)  # (W, Q)
        me = jax.lax.axis_index(ax)
        remote_l = (sent_l.sum(axis=0) - sent_l[me]).astype(
            routing.TRAFFIC_DTYPE)  # (Q,)

        # ---- pack + one all_to_all per leaf: ids, lane mask, lane values
        def pack(leafT, fill):
            shape = (W * c + 1,) + leafT.shape[1:]
            buf = jnp.full(shape, fill, leafT.dtype)
            return buf.at[slot].set(leafT, mode="drop")[: W * c]

        recv_ids = jax.lax.all_to_all(
            pack(u_dst, routing.BIG).reshape(W, c), ax, 0, 0, tiled=True)
        recv_has = jax.lax.all_to_all(
            pack(lanes, False).reshape(W, c, q), ax, 0, 0, tiled=True)
        vmat = jnp.where(
            lanes[:, :, None], jnp.moveaxis(u_vals, 0, 1), ident)
        recv_v = jax.lax.all_to_all(
            pack(vmat, ident).reshape(W, c, q, d), ax, 0, 0, tiled=True)

        # ---- receiver-side per-lane combine: one segment pass over Q·D
        flat_ids = recv_ids.reshape(-1)
        flat_has = recv_has.reshape(W * c, q)
        dst_local = jnp.where(
            flat_ids != routing.BIG, flat_ids - me * n_loc, n_loc
        ).astype(jnp.int32)
        flat_v = jnp.where(
            flat_has[:, :, None], recv_v.reshape(W * c, q, d), ident)
        out = kops.segment_combine(
            flat_v.reshape(W * c, q * d), dst_local, n_loc, combiner,
            use_kernel=False)
        out = jnp.moveaxis(out.reshape(n_loc, q, d), 0, 1)  # (Q, n_loc, D)
        got = jnp.moveaxis(
            jax.ops.segment_sum(
                flat_has.astype(jnp.int32), dst_local, n_loc) > 0,
            0, 1)  # (Q, n_loc)
        return (out, got, ovf_l, remote_l), (True, True, True, True)

    return ex(ctx.query_index, routing.lane_live(ctx),
              jnp.asarray(dst, jnp.int32), valid, v)


def combined_send(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    vals: jax.Array,
    combiner,
    capacity: int,
    *,
    name: str = "combined_message",
    use_kernel: Optional[bool] = None,
    wire_width: int = None,
):
    """CombinedMessage: sender-side combine per destination, route, then
    receiver-side combine to a dense (n_loc, D) array.

    Under the batched query plane (``route_batch="union"``) the dedup +
    route happen once over the union frontier of all Q lanes, provided
    the combiner is union-exact; otherwise the serial body runs per lane.

    Returns (combined (n_loc,[D]), got_any (n_loc,) bool, overflow).
    """
    combiner = cb.get(combiner)
    capacity = ctx.scale_capacity(name, capacity)
    squeeze = vals.ndim == 1
    v = vals[:, None] if squeeze else vals
    d = v.shape[1]

    if (getattr(ctx, "batched", False)
            and routing.resolve_batch() == "union"
            and _union_exact(combiner, v.dtype)):
        out, got, overflow, remote = _combined_send_union(
            ctx, dst, valid, v, combiner, capacity, use_kernel)
    else:
        out, got, overflow, remote = _combined_send_serial(
            ctx, dst, valid, v, combiner, capacity, use_kernel)

    width = 4 + (wire_width if wire_width is not None
                 else d * jnp.dtype(v.dtype).itemsize)
    ctx.add_traffic(name, remote * width, remote)
    ctx.add_overflow(name, overflow)
    return (out[:, 0] if squeeze else out), got, overflow


def monolithic_send(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    payload,
    capacity: int,
    *,
    pad_width: int,
    name: str = "pregel_message",
) -> Delivery:
    """Pregel-monolithic emulation (Table IV baseline): every message is
    padded to the program-wide maximum message width `pad_width`, and no
    per-channel combiner can be applied."""
    capacity = ctx.scale_capacity(name, capacity)
    routed = _route_maybe_union(ctx, dst, valid, payload, capacity)
    remote = routing.remote_count(ctx, routed.sent_count)
    ctx.add_traffic(name, remote * (4 + pad_width), remote)
    ctx.add_overflow(name, routed.overflow)
    return _delivery(ctx, routed, capacity)
