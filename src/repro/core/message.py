"""Standard message-passing channels (paper Table I).

DirectMessage — arbitrary (dst, payload) messages; the receiver iterates
over deliveries. CombinedMessage — a combiner is applied both sender-side
(per destination, before the exchange) and receiver-side, yielding a dense
per-vertex combined value. Both use the dynamic routed exchange
(``repro.core.routing``, one-pass bucket routing by default), and both
put destination ids on the wire — the costs the optimized channels remove.

The CombinedMessage sender-side combine is sort-free: the
unique-destination list is compacted with a counting prefix-sum
(``routing.dedup_dense``) and values are reduced directly in that
compact space — O(M·W + N) work with only an int32 histogram as the
N-sized transient, no ``argsort`` anywhere on the dynamic data plane
(non-lattice combiners such as ``min_by_first`` still sort inside their
``segment_reduce``). ``id_bytes`` are charged once per *wire* message
(the post-dedup, capacity-packed sends that actually cross a worker
boundary), never per enqueued send.

Registry contract (fused runtime): every send is traced unconditionally —
an empty `valid` mask yields zero accounted traffic rather than a skipped
``add_traffic`` call, so the per-step stats pytree keeps a fixed shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import combiners as cb
from repro.core import routing
from repro.core.channel import ChannelContext, payload_width
from repro.kernels import ops as kops


@dataclasses.dataclass
class Delivery:
    """Messages delivered to this worker (flattened over peers)."""

    dst_local: jax.Array   # (K,) int32 local destination index (n_loc pad)
    payload: Any           # pytree of (K, ...) arrays
    mask: jax.Array        # (K,) bool
    overflow: jax.Array    # () bool


def _delivery(ctx: ChannelContext, routed: routing.Routed, capacity: int):
    """Flatten a Routed into per-message local-index delivery form."""
    w, c = ctx.num_workers, capacity
    flat = jax.tree_util.tree_map(
        lambda x: x.reshape((w * c,) + x.shape[2:]), routed.payload
    )
    ids = routed.ids.reshape(-1)
    dst_local = jnp.where(
        routed.mask.reshape(-1), ids - ctx.me() * ctx.n_loc, ctx.n_loc
    ).astype(jnp.int32)
    return Delivery(
        dst_local=dst_local,
        payload=flat,
        mask=routed.mask.reshape(-1),
        overflow=routed.overflow,
    )


def direct_send(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    payload,
    capacity: int,
    *,
    name: str = "direct_message",
    id_bytes: int = 4,
    wire_width: int = None,
) -> Delivery:
    """DirectMessage: deliver (dst, payload) messages to dst's owner.

    wire_width overrides the accounted per-message payload width (used by
    the monolithic-Pregel emulation where every message is padded to the
    program-wide maximum message type)."""
    routed = routing.route(ctx, dst, valid, payload, capacity)
    remote = routing.remote_count(ctx, routed.sent_count)
    width = id_bytes + (wire_width if wire_width is not None
                        else payload_width(payload))
    ctx.add_traffic(name, remote * width, remote)
    return _delivery(ctx, routed, capacity)


def combined_send(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    vals: jax.Array,
    combiner,
    capacity: int,
    *,
    name: str = "combined_message",
    use_kernel: Optional[bool] = None,
    wire_width: int = None,
):
    """CombinedMessage: sender-side combine per destination, route, then
    receiver-side combine to a dense (n_loc, D) array.

    Returns (combined (n_loc,[D]), got_any (n_loc,) bool, overflow).
    """
    combiner = cb.get(combiner)
    squeeze = vals.ndim == 1
    v = vals[:, None] if squeeze else vals
    m, d = v.shape
    n_total = ctx.num_workers * ctx.n_loc
    ident = combiner.ident_for(v.dtype)

    # sender-side combine, sort-free: compact the occupied destinations
    # into an ascending unique list (counting prefix-sum over the id
    # space), then reduce the values directly in that compact space —
    # the only O(N_global) transient is dedup_dense's int32 histogram;
    # values never materialize densely.
    u_dst, pos = routing.dedup_dense(dst, valid, n_total)
    u_valid = u_dst != routing.BIG
    seg = jnp.where(
        valid, pos[jnp.clip(dst.astype(jnp.int32), 0, n_total - 1)], m
    )
    u_vals = combiner.segment_reduce(v, seg, m)  # (m, d), u_dst-aligned

    routed = routing.route(
        ctx, u_dst, u_valid, {"v": u_vals}, capacity, use_kernel=use_kernel
    )
    remote = routing.remote_count(ctx, routed.sent_count)
    width = 4 + (wire_width if wire_width is not None
                 else d * jnp.dtype(v.dtype).itemsize)
    ctx.add_traffic(name, remote * width, remote)

    deliv = _delivery(ctx, routed, capacity)
    flat_v = jnp.where(deliv.mask[:, None], deliv.payload["v"], ident)
    out = kops.segment_combine(flat_v, deliv.dst_local, ctx.n_loc, combiner,
                               use_kernel=False)
    got = (
        jax.ops.segment_sum(
            deliv.mask.astype(jnp.int32), deliv.dst_local, ctx.n_loc
        )
        > 0
    )
    return (out[:, 0] if squeeze else out), got, routed.overflow


def monolithic_send(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    payload,
    capacity: int,
    *,
    pad_width: int,
    name: str = "pregel_message",
) -> Delivery:
    """Pregel-monolithic emulation (Table IV baseline): every message is
    padded to the program-wide maximum message width `pad_width`, and no
    per-channel combiner can be applied."""
    routed = routing.route(ctx, dst, valid, payload, capacity)
    remote = routing.remote_count(ctx, routed.sent_count)
    ctx.add_traffic(name, remote * (4 + pad_width), remote)
    return _delivery(ctx, routed, capacity)
