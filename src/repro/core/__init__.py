"""repro.core — the paper's contribution: composable communication channels
(§IV channel library, §V composition; see docs/channels.md and
docs/composition.md for the module ↔ paper-section map).

Import order matters: combiners first (the kernels depend on it), then
compose (the channel modules' exchange/fusion layer), then the channel
modules (which depend on the kernels).
"""
from repro.core import combiners  # noqa: F401  (must be first)
from repro.core.channel import ChannelContext, payload_width  # noqa: F401
from repro.core import compose  # noqa: F401  (before the channel modules)
from repro.core import routing  # noqa: F401
from repro.core import aggregator  # noqa: F401
from repro.core import message  # noqa: F401
from repro.core import scatter_combine  # noqa: F401
from repro.core import request_respond  # noqa: F401
from repro.core import propagation  # noqa: F401
from repro.core import segmented  # noqa: F401
