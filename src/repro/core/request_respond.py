"""Request-Respond channel (paper §IV-C2).

Every vertex may request an attribute of any other vertex. The channel
dedups requests to the same destination per worker (sort + unique), sends
only unique ids, and the responder replies with a *positionally ordered
value list* — no ids on the respond wire. This is the paper's fix for the
respond-phase imbalance caused by high-degree vertices, plus its byte
trick (reply in request order).

Registry contract (fused runtime): the channel contributes two fixed stat
keys — ``<name>/request`` and ``<name>/respond`` — on every trace, even
when no request is valid (zero traffic, not a missing key).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import routing
from repro.core.channel import ChannelContext


def request(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    respond_vals: jax.Array,
    capacity: int,
    *,
    name: str = "request_respond",
):
    """Request `respond_vals[dst]` for each valid request.

    Args:
      dst: (R,) int32 global ids to query.
      valid: (R,) bool.
      respond_vals: (n_loc,) or (n_loc, D) — the per-vertex attribute the
        responder exposes (the paper's user-provided f(vertex)).
      capacity: per-peer unique-request capacity.
    Returns:
      (resp (R,[D]), overflow) — responses aligned with `dst` (zeros for
      invalid requests).
    """
    squeeze = respond_vals.ndim == 1
    rv = respond_vals[:, None] if squeeze else respond_vals
    d = rv.shape[-1]
    r = dst.shape[0]

    # --- dedup: sort by destination, keep one entry per unique dst ---
    key = jnp.where(valid, dst.astype(jnp.int32), routing.BIG)
    order = jnp.argsort(key)
    sdst = key[order]
    prev = jnp.concatenate([jnp.full((1,), -1, sdst.dtype), sdst[:-1]])
    first = (sdst != prev) & (sdst != routing.BIG)
    run = jnp.cumsum(first.astype(jnp.int32)) - 1
    u_dst = jnp.full((r + 1,), routing.BIG, jnp.int32)
    u_dst = u_dst.at[jnp.where(first, run, r)].set(sdst, mode="drop")[:r]
    u_valid = u_dst != routing.BIG

    # --- request phase: ids only ---
    routed = routing.route(ctx, u_dst, u_valid, {}, capacity)
    remote = routing.remote_count(ctx, routed.sent_count)
    ctx.add_traffic(name + "/request", remote * 4, remote)

    # --- respond phase: positional values, no ids ---
    lidx = jnp.where(routed.mask, routed.ids - ctx.me() * ctx.n_loc, ctx.n_loc)
    rv_pad = jnp.concatenate([rv, jnp.zeros((1, d), rv.dtype)], axis=0)
    resp = rv_pad[jnp.clip(lidx, 0, ctx.n_loc)]  # (W, C, D)
    back = routing.reply(ctx, routed, {"v": resp}, m=r)["v"]  # (R, D) per-unique
    ctx.add_traffic(
        name + "/respond", remote * d * jnp.dtype(rv.dtype).itemsize, remote
    )

    # --- expand to all requests (sorted order), then un-permute ---
    per_sorted = back[jnp.clip(run, 0, r - 1)]
    per_sorted = jnp.where((sdst != routing.BIG)[:, None], per_sorted, 0)
    out = jnp.zeros((r, d), rv.dtype).at[order].set(per_sorted, mode="drop")
    return (out[:, 0] if squeeze else out), routed.overflow
