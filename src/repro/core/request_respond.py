"""Request-Respond channel (paper §IV-C2).

Every vertex may request an attribute of any other vertex. The channel
dedups requests to the same destination per worker (a counting
prefix-sum compaction — ``routing.dedup_dense``, no sort), sends only
unique ids, and the responder replies with a *positionally ordered value
list* — no ids on the respond wire. This is the paper's fix for the
respond-phase imbalance caused by high-degree vertices, plus its byte
trick (reply in request order). Traffic is charged per *wire* message:
the post-dedup unique ids on the request wire, the positional values on
the respond wire.

Registry contract (fused runtime): the channel contributes two fixed stat
keys — ``<name>/request`` and ``<name>/respond`` — on every trace, even
when no request is valid (zero traffic, not a missing key).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap

from repro.core import routing
from repro.core.channel import ChannelContext


def _request_core(ctx, dst, valid, rv, capacity):
    """The serial request/respond body (also the per-lane body under
    ``route_batch="lane"``). Returns (out (R, D), overflow (), remote ())
    — traffic is charged by the caller."""
    d = rv.shape[-1]
    r = dst.shape[0]
    n_total = ctx.num_workers * ctx.n_loc

    # --- dedup: one compact entry per unique destination (sort-free) ---
    u_dst, pos = routing.dedup_dense(dst, valid, n_total)
    u_valid = u_dst != routing.BIG

    # --- request phase: ids only ---
    routed = routing.route(ctx, u_dst, u_valid, {}, capacity)
    remote = routing.remote_count(ctx, routed.sent_count)

    # --- respond phase: positional values, no ids ---
    lidx = jnp.where(routed.mask, routed.ids - ctx.me() * ctx.n_loc, ctx.n_loc)
    rv_pad = jnp.concatenate([rv, jnp.zeros((1, d), rv.dtype)], axis=0)
    resp = rv_pad[jnp.clip(lidx, 0, ctx.n_loc)]  # (W, C, D)
    back = routing.reply(ctx, routed, {"v": resp})["v"]  # per-unique rows

    # --- expand to all requests: each request gathers its unique row ---
    idx = pos[jnp.clip(dst.astype(jnp.int32), 0, n_total - 1)]
    per_req = back[jnp.clip(idx, 0, max(r - 1, 0))]
    out = jnp.where(valid[:, None], per_req, 0)
    return out, routed.overflow, remote


def _request_union(ctx, dst, valid, rv, capacity):
    """Request/respond across Q query lanes with ONE dedup + route pass
    over the union of the lanes' request sets. Unique ids cross the wire
    once per worker pair regardless of how many lanes ask; responses come
    back as a positional (slots, Q·D) lane matrix, and each lane gathers
    only the rows it asked for. Pure gather — bit-identical to the serial
    body per lane whenever the union pass does not overflow."""
    W, n_loc, ax = ctx.num_workers, ctx.n_loc, ctx.axis
    n_total = W * n_loc
    r = dst.shape[0]
    d = rv.shape[-1]
    c = capacity
    impl = routing.resolve_impl(None)

    @custom_vmap
    def ex(qidx, live, dst, valid, rv):
        return _request_core(ctx, dst, valid & live, rv, c)

    @ex.def_vmap
    def _rule(axis_size, in_batched, qidx, live, dst, valid, rv):
        q = axis_size
        _, lb, db, vb, rb = in_batched
        live2 = live if lb else jnp.broadcast_to(live, (q,))
        valid2 = valid if vb else jnp.broadcast_to(valid, (q, r))
        valid_eff = valid2 & live2[:, None]  # (Q, R)
        dst2 = (dst if db else jnp.broadcast_to(dst, (q, r))).astype(jnp.int32)
        rv2 = rv if rb else jnp.broadcast_to(rv, (q, n_loc, d))

        # ---- union dedup: one compact entry per unique id ANY lane asks
        u_cap = min(q * r, n_total)
        u_dst, pos = routing.union_dedup(dst2, valid_eff, n_total, u_cap)
        u_valid = u_dst != routing.BIG
        seg_l = jnp.where(
            valid_eff, pos[jnp.clip(dst2, 0, n_total - 1)], u_cap)  # (Q, R)
        lane_has = (
            jnp.zeros((q, u_cap + 1), jnp.int32)
            .at[jnp.arange(q)[:, None], seg_l]
            .add(1)[:, :u_cap]
            > 0
        )  # (Q, u_cap)

        # ---- ONE route pass over the union unique list ----
        owner_u = jnp.clip(u_dst // n_loc, 0, W - 1)
        key_u = jnp.where(u_valid, owner_u, W).astype(jnp.int32)
        lanes = lane_has.T  # (u_cap, Q)
        rank, count, lane_counts = routing.union_ranks(
            key_u, lanes, W, impl=impl)
        fits = rank < c
        packed = u_valid & fits
        slot = jnp.where(packed, key_u * c + rank, W * c)
        ovf_l = jnp.any(lane_has & ~fits[None, :], axis=1)  # (Q,)
        sent_l = jnp.minimum(lane_counts, c)  # (W, Q)
        me = jax.lax.axis_index(ax)
        remote_l = (sent_l.sum(axis=0) - sent_l[me]).astype(
            routing.TRAFFIC_DTYPE)  # (Q,)

        # ---- request wire: shared unique ids, one all_to_all ----
        ids_buf = jnp.full((W * c + 1,), routing.BIG, jnp.int32)
        ids_buf = ids_buf.at[slot].set(u_dst, mode="drop")[: W * c]
        recv_ids = jax.lax.all_to_all(
            ids_buf.reshape(W, c), ax, 0, 0, tiled=True)  # (W, C)

        # ---- respond wire: positional (slots, Q, D) lane matrix ----
        lidx = jnp.where(
            recv_ids != routing.BIG, recv_ids - me * n_loc, n_loc)
        rv_pad = jnp.concatenate(
            [rv2, jnp.zeros((q, 1, d), rv2.dtype)], axis=1)  # (Q, n_loc+1, D)
        resp = rv_pad[:, jnp.clip(lidx, 0, n_loc)]  # (Q, W, C, D)
        back = jax.lax.all_to_all(
            jnp.moveaxis(resp, 0, 2), ax, 0, 0, tiled=True)  # (W, C, Q, D)
        flat = jnp.concatenate(
            [back.reshape(W * c, q, d), jnp.zeros((1, q, d), rv2.dtype)], 0)
        back_u = flat[jnp.minimum(slot, W * c)]  # (u_cap, Q, D)

        # ---- each lane gathers its own requests' unique rows ----
        idx_l = jnp.clip(seg_l, 0, max(u_cap - 1, 0))  # (Q, R)
        per_req = back_u[idx_l, jnp.arange(q)[:, None]]  # (Q, R, D)
        out = jnp.where(valid_eff[:, :, None], per_req, 0)
        return (out, ovf_l, remote_l), (True, True, True)

    return ex(ctx.query_index, routing.lane_live(ctx),
              jnp.asarray(dst, jnp.int32), valid, rv)


def request(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    respond_vals: jax.Array,
    capacity: int,
    *,
    name: str = "request_respond",
):
    """Request `respond_vals[dst]` for each valid request.

    Args:
      dst: (R,) int32 global ids to query.
      valid: (R,) bool.
      respond_vals: (n_loc,) or (n_loc, D) — the per-vertex attribute the
        responder exposes (the paper's user-provided f(vertex)).
      capacity: per-peer unique-request capacity.
    Returns:
      (resp (R,[D]), overflow) — responses aligned with `dst` (zeros for
      invalid requests).
    """
    squeeze = respond_vals.ndim == 1
    rv = respond_vals[:, None] if squeeze else respond_vals
    d = rv.shape[-1]
    capacity = ctx.scale_capacity(name + "/request", capacity)

    if getattr(ctx, "batched", False) and routing.resolve_batch() == "union":
        out, overflow, remote = _request_union(ctx, dst, valid, rv, capacity)
    else:
        out, overflow, remote = _request_core(ctx, dst, valid, rv, capacity)

    ctx.add_traffic(name + "/request", remote * 4, remote)
    ctx.add_traffic(
        name + "/respond", remote * d * jnp.dtype(rv.dtype).itemsize, remote
    )
    ctx.add_overflow(name + "/request", overflow)
    return (out[:, 0] if squeeze else out), overflow
