"""Request-Respond channel (paper §IV-C2).

Every vertex may request an attribute of any other vertex. The channel
dedups requests to the same destination per worker (a counting
prefix-sum compaction — ``routing.dedup_dense``, no sort), sends only
unique ids, and the responder replies with a *positionally ordered value
list* — no ids on the respond wire. This is the paper's fix for the
respond-phase imbalance caused by high-degree vertices, plus its byte
trick (reply in request order). Traffic is charged per *wire* message:
the post-dedup unique ids on the request wire, the positional values on
the respond wire.

Registry contract (fused runtime): the channel contributes two fixed stat
keys — ``<name>/request`` and ``<name>/respond`` — on every trace, even
when no request is valid (zero traffic, not a missing key).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import routing
from repro.core.channel import ChannelContext


def request(
    ctx: ChannelContext,
    dst: jax.Array,
    valid: jax.Array,
    respond_vals: jax.Array,
    capacity: int,
    *,
    name: str = "request_respond",
):
    """Request `respond_vals[dst]` for each valid request.

    Args:
      dst: (R,) int32 global ids to query.
      valid: (R,) bool.
      respond_vals: (n_loc,) or (n_loc, D) — the per-vertex attribute the
        responder exposes (the paper's user-provided f(vertex)).
      capacity: per-peer unique-request capacity.
    Returns:
      (resp (R,[D]), overflow) — responses aligned with `dst` (zeros for
      invalid requests).
    """
    squeeze = respond_vals.ndim == 1
    rv = respond_vals[:, None] if squeeze else respond_vals
    d = rv.shape[-1]
    r = dst.shape[0]
    n_total = ctx.num_workers * ctx.n_loc

    # --- dedup: one compact entry per unique destination (sort-free) ---
    u_dst, pos = routing.dedup_dense(dst, valid, n_total)
    u_valid = u_dst != routing.BIG

    # --- request phase: ids only ---
    routed = routing.route(ctx, u_dst, u_valid, {}, capacity)
    remote = routing.remote_count(ctx, routed.sent_count)
    ctx.add_traffic(name + "/request", remote * 4, remote)

    # --- respond phase: positional values, no ids ---
    lidx = jnp.where(routed.mask, routed.ids - ctx.me() * ctx.n_loc, ctx.n_loc)
    rv_pad = jnp.concatenate([rv, jnp.zeros((1, d), rv.dtype)], axis=0)
    resp = rv_pad[jnp.clip(lidx, 0, ctx.n_loc)]  # (W, C, D)
    back = routing.reply(ctx, routed, {"v": resp})["v"]  # (R, D) per-unique
    ctx.add_traffic(
        name + "/respond", remote * d * jnp.dtype(rv.dtype).itemsize, remote
    )

    # --- expand to all requests: each request gathers its unique row ---
    idx = pos[jnp.clip(dst.astype(jnp.int32), 0, n_total - 1)]
    per_req = back[jnp.clip(idx, 0, max(r - 1, 0))]
    out = jnp.where(valid[:, None], per_req, 0)
    return (out[:, 0] if squeeze else out), routed.overflow
