"""Pallas TPU kernel: one-pass stable bucket ranking (the routing hot loop).

Ownership in this library is by contiguous vertex-id range, so a routed
exchange only needs *owner order*, not full destination order: a message's
wire slot is ``owner * C + rank`` where ``rank`` is its stable arrival
rank within the owner bucket. That rank is a counting sort — O(M) against
the O(M log M) ``argsort`` it replaces — and maps onto the TPU as a
single sequential sweep over message chunks:

  - the message keys (owner per message, already clipped; ``B`` = invalid
    sentinel) are tiled into chunks of ``block_msgs``,
  - a ``(B + 1,)`` running-occupancy vector lives in the revisited counts
    output (the canonical Pallas accumulator pattern: initialized at grid
    step 0, read-modify-written by every step),
  - inside a chunk the per-bucket arrival ranks are a one-hot
    ``jnp.cumsum`` on the VPU (buckets are the worker count — a few
    lanes), offset by the running occupancy carried in from the previous
    chunks.

Grid: ``(num_chunks,)``, iterated sequentially on one core — exactly the
property that makes the running counts carry correct.  The actual scatter
into the ``(W, C, ...)`` send buffer stays outside the kernel (a plain
``.at[slot].set``): the expensive part of the routing was never the
scatter, it was computing the permutation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(key_ref, rank_ref, counts_ref, *, num_buckets):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    keys = key_ref[:, 0]  # (BM,) bucket id per message, B = invalid
    cols = jax.lax.broadcasted_iota(
        jnp.int32, (keys.shape[0], num_buckets + 1), 1
    )
    onehot = (keys[:, None] == cols).astype(jnp.int32)  # (BM, B+1)
    base = counts_ref[0, :]  # (B+1,) occupancy before this chunk
    within = jnp.cumsum(onehot, axis=0) - 1  # arrival rank inside the chunk
    # one-hot rows are exact selectors: sum picks rank for this key only
    rank = jnp.sum(onehot * (within + base[None, :]), axis=1)
    rank_ref[:, 0] = rank
    counts_ref[0, :] = base + onehot.sum(axis=0)


def _kernel_lanes(key_ref, lane_ref, rank_ref, counts_ref, lane_counts_ref,
                  *, num_buckets):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        lane_counts_ref[...] = jnp.zeros_like(lane_counts_ref)

    keys = key_ref[:, 0]
    cols = jax.lax.broadcasted_iota(
        jnp.int32, (keys.shape[0], num_buckets + 1), 1
    )
    onehot = (keys[:, None] == cols).astype(jnp.int32)  # (BM, B+1)
    base = counts_ref[0, :]
    within = jnp.cumsum(onehot, axis=0) - 1
    rank = jnp.sum(onehot * (within + base[None, :]), axis=1)
    rank_ref[:, 0] = rank
    counts_ref[0, :] = base + onehot.sum(axis=0)
    # per-lane per-bucket histogram delta for this chunk: one
    # (B+1, BM) x (BM, Q) contraction — an MXU matmul on TPU. float32
    # accumulation is exact here (counts are bounded by M << 2^24).
    lanes = lane_ref[...]  # (BM, Q) membership
    delta = jax.lax.dot_general(
        onehot.astype(jnp.float32),
        lanes.astype(jnp.float32),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    lane_counts_ref[...] = lane_counts_ref[...] + delta.astype(jnp.int32)


def bucket_ranks_pallas(
    keys,
    *,
    num_buckets: int,
    block_msgs: int = 512,
    interpret: bool = True,
):
    """Stable per-bucket arrival ranks via a sequential counting sweep.

    Args:
      keys: (M_pad,) int32 bucket per message in ``[0, num_buckets]``;
        ``num_buckets`` is the invalid sentinel (still ranked, so padded
        tails are harmless). ``M_pad`` must be a multiple of
        ``block_msgs``.
      num_buckets: static bucket count B (the worker count).
      block_msgs: chunk length per grid step.
    Returns:
      (rank, counts): (M_pad,) int32 stable rank within bucket and the
      (B + 1,) final occupancy histogram (sentinel bucket last).
    """
    m = keys.shape[0]
    assert m % block_msgs == 0, (m, block_msgs)
    grid = (m // block_msgs,)
    kernel = functools.partial(_kernel, num_buckets=num_buckets)
    rank, counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_msgs, 1), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((block_msgs, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, num_buckets + 1), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, num_buckets + 1), jnp.int32),
        ),
        interpret=interpret,
    )(jnp.asarray(keys, jnp.int32)[:, None])
    return rank[:, 0], counts[0]


def bucket_ranks_lanes_pallas(
    keys,
    lanes,
    *,
    num_buckets: int,
    block_msgs: int = 512,
    interpret: bool = True,
):
    """Q-aware bucket ranking: the same sequential counting sweep as
    :func:`bucket_ranks_pallas`, fused with the per-lane per-bucket
    membership histogram the batched (union-frontier) data plane charges
    traffic from — one pass over the union key list instead of Q.

    Args:
      keys: (M_pad,) int32 bucket per union entry in ``[0, num_buckets]``
        (``num_buckets`` = invalid sentinel); M_pad a ``block_msgs``
        multiple.
      lanes: (M_pad, Q) int32 lane membership (0/1); padded tail rows
        must be all-zero.
    Returns:
      (rank (M_pad,), counts (B + 1,), lane_counts (B + 1, Q)).
    """
    m = keys.shape[0]
    q = lanes.shape[1]
    assert m % block_msgs == 0, (m, block_msgs)
    assert lanes.shape[0] == m, (lanes.shape, m)
    grid = (m // block_msgs,)
    kernel = functools.partial(_kernel_lanes, num_buckets=num_buckets)
    rank, counts, lane_counts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_msgs, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_msgs, q), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_msgs, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, num_buckets + 1), lambda i: (0, 0)),
            pl.BlockSpec((num_buckets + 1, q), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, num_buckets + 1), jnp.int32),
            jax.ShapeDtypeStruct((num_buckets + 1, q), jnp.int32),
        ),
        interpret=interpret,
    )(jnp.asarray(keys, jnp.int32)[:, None], jnp.asarray(lanes, jnp.int32))
    return rank[:, 0], counts[0], lane_counts
