"""Pure-jnp oracles for the Pallas kernels in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import combiners as cb


def segment_combine_ref(vals, seg_ids, num_segments, combiner):
    """Segment reduction oracle.

    Args:
      vals: (E, D) values (padded entries must carry the combiner identity
        or a seg_id >= num_segments).
      seg_ids: (E,) int32 destination segment per value.
      num_segments: static int, number of output rows.
      combiner: repro.core.combiners.Combiner or name.
    Returns:
      (num_segments, D) combined values; empty segments hold the identity.
    """
    combiner = cb.get(combiner)
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    # Entries with seg >= num_segments are dropped by segment_* semantics
    # (indices out of range are ignored in jax.ops.segment_* with
    # indices_are_sorted=False and num_segments given).
    return combiner.segment_reduce(vals, seg_ids, num_segments)


def bucket_ranks_ref(keys, num_buckets):
    """Stable counting-scatter oracle for the bucket-route kernel.

    Args:
      keys: (M,) int32 bucket per message in ``[0, num_buckets]`` —
        ``num_buckets`` itself is the invalid/dropped sentinel.
      num_buckets: static int B (e.g. the worker count W).
    Returns:
      (rank, counts) — ``rank[i]`` is the arrival rank of message ``i``
      within its bucket (stable: original order preserved), ``counts``
      is the (B,) occupancy histogram over the real buckets.

    O(M·B) work via an (M, B+1) one-hot cumsum — the intended regime is
    B = the worker count, a modest constant, where this is a pure win
    over the O(M log M) argsort it replaces (see ``core/routing.py``).
    """
    keys = jnp.asarray(keys, jnp.int32)
    onehot = (
        keys[:, None] == jnp.arange(num_buckets + 1, dtype=jnp.int32)[None, :]
    ).astype(jnp.int32)
    rank = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=0) - 1, keys[:, None], axis=1
    )[:, 0]
    return rank, onehot[:, :num_buckets].sum(axis=0)


def bucket_ranks_lanes_ref(keys, lanes, num_buckets):
    """Q-aware oracle for the union-frontier bucket route: the *shared*
    stable ranks/occupancy over the union key list (identical to
    :func:`bucket_ranks_ref`) plus the per-lane per-bucket membership
    histogram — the quantity the batched data plane needs to attribute
    wire traffic to each query lane without a second pass.

    Args:
      keys: (M,) int32 bucket per union entry in ``[0, num_buckets]``
        (``num_buckets`` = invalid sentinel).
      lanes: (M, Q) lane membership (bool or 0/1 int) — lane q enqueued
        the entry. Membership of an invalid entry must be all-False.
      num_buckets: static int B (the worker count W).
    Returns:
      (rank (M,), counts (B,), lane_counts (B, Q)) — ``lane_counts[b, q]``
      is how many of lane q's entries landed in bucket b.
    """
    keys = jnp.asarray(keys, jnp.int32)
    rank, counts = bucket_ranks_ref(keys, num_buckets)
    lane_counts = jax.ops.segment_sum(
        jnp.asarray(lanes, jnp.int32), keys, num_buckets + 1
    )[:num_buckets]
    return rank, counts, lane_counts


def gather_segment_combine_ref(src_vals, edge_src, seg_ids, num_segments, combiner):
    """Fused gather + segment reduction oracle (the SpMV-style hot loop).

    Args:
      src_vals: (N_src, D) per-source values.
      edge_src: (E,) int32 source index per edge (padded edges may point
        anywhere valid; they must carry seg_ids >= num_segments).
      seg_ids: (E,) int32 destination segment per edge.
    """
    combiner = cb.get(combiner)
    vals = src_vals[jnp.asarray(edge_src, jnp.int32)]
    return combiner.segment_reduce(vals, jnp.asarray(seg_ids, jnp.int32), num_segments)
