"""Jit-ready wrappers around the Pallas kernels, plan building, and the
kernel-path configuration surface.

``segment_combine`` is the public entry point used by the channels: it
dispatches to the Pallas kernel or to the pure-jnp reference depending on
``use_kernel``. The kernel path expects sorted segment ids (the
scatter-combine channel guarantees this by construction — that is the
paper's preprocessing insight). ``bucket_ranks`` is the analogous entry
point for the routing data plane (stable counting-sort ranks).

Configuration — resolved by :func:`resolve_use_kernel`, most specific
wins:

  1. an explicit ``use_kernel=`` argument at a call site;
  2. the :func:`use_kernel_scope` context (how ``Engine(use_kernel=...)``
     threads the knob through a compile);
  3. the ``REPRO_USE_KERNEL`` environment variable (``1/true/yes/on``);
  4. the backend default: **on** for TPU (the kernels are the fast path
     there), off elsewhere (the interpret-mode kernel is a correctness
     vehicle on CPU, not a fast path).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import knobs
from repro.core import combiners as cb
from repro.kernels import bucket_route as kbucket
from repro.kernels import ref as kref
from repro.kernels import segment_combine as kseg

#: the kernel-vs-reference knob (explicit > use_kernel_scope >
#: REPRO_USE_KERNEL > backend default) — see repro.configs.knobs
USE_KERNEL = knobs.Knob(
    "use_kernel", env="REPRO_USE_KERNEL",
    default=lambda: jax.default_backend() == "tpu",
    parse=knobs.parse_bool, coerce=bool)


def resolve_use_kernel(use_kernel: Optional[bool] = None) -> bool:
    """The kernel-vs-reference decision for a call site (see module doc)."""
    return USE_KERNEL.resolve(use_kernel)


def use_kernel_scope(use_kernel: Optional[bool]):
    """Pin the kernel decision for every channel call under the scope
    (trace-time: wrap the compile, not the execution)."""
    return USE_KERNEL.scope(use_kernel)


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Pallas interpret mode: real lowering on TPU, interpreter elsewhere."""
    if interpret is not None:
        return bool(interpret)
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


# ---------------------------------------------------------------------------
# block-plan autotune (host-side, consumed by graph.pgraph.ScatterPlan)
# ---------------------------------------------------------------------------


def autotune_block_sizes(u_cap: int, e_cap: int) -> Tuple[int, int]:
    """Choose (block_rows, block_edges) for a sorted-segment combine from
    the edge distribution of a plan.

    Heuristic: size the output tile to the segment count (small graphs
    should not pad 8x past their rows), then size the edge chunk so one
    chunk covers roughly the edges of one row block (``avg_deg *
    block_rows``) — each row block then visits O(1) chunks, which is what
    keeps the revisited-output reduction grid shallow.
    """
    block_rows = min(128, max(8, _next_pow2(u_cap)))
    avg_deg = e_cap / max(u_cap, 1)
    block_edges = min(2048, max(128, _next_pow2(int(avg_deg * block_rows))))
    return block_rows, block_edges


def build_chunk_plan(seg_ids_np, num_segments, block_rows, block_edges):
    """Host-side (numpy) plan: covering chunk range per output row block.

    Returns (chunk_start, num_chunks, max_chunks) for sorted seg_ids.
    """
    seg = np.asarray(seg_ids_np)
    nb = _round_up(num_segments, block_rows) // block_rows
    bounds = np.searchsorted(seg, np.arange(nb + 1) * block_rows, side="left")
    lo, hi = bounds[:-1], bounds[1:]
    cs = lo // block_edges
    ce = -(-hi // block_edges)  # ceil
    nc = np.where(hi > lo, ce - cs, 0).astype(np.int32)
    return cs.astype(np.int32), nc, int(nc.max(initial=0))


def plan_chunks(seg_ids_np, num_segments, block_rows, block_edges):
    """build_chunk_plan against the *kernel's* padded view of the inputs:
    entries >= num_segments map to the padded row bound and the edge axis
    is padded to a block_edges multiple — exactly what
    :func:`segment_combine` does internally, so a plan built here can be
    passed as its ``chunk_plan`` (the ScatterPlan autotune path)."""
    seg = np.asarray(seg_ids_np)
    n_pad = _round_up(max(num_segments, 1), block_rows)
    e_pad = _round_up(max(len(seg), 1), block_edges)
    seg = np.where((seg < 0) | (seg >= num_segments), n_pad, seg)
    seg = np.concatenate([seg, np.full(e_pad - len(seg), n_pad, seg.dtype)])
    return build_chunk_plan(seg, num_segments, block_rows, block_edges)


# ---------------------------------------------------------------------------
# segment combine (scatter-combine hot loop)
# ---------------------------------------------------------------------------


def segment_combine(
    vals,
    seg_ids,
    num_segments: int,
    combiner,
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
    block_rows: int = 128,
    block_edges: int = 512,
    chunk_plan=None,
    assume_sorted: bool = False,
):
    """Segment reduction: out[s] = combine(vals[e] for seg_ids[e] == s).

    Entries with seg_ids >= num_segments are dropped. The kernel path
    requires sorted seg_ids (assume_sorted or it sorts internally).
    """
    combiner = cb.get(combiner)
    if not resolve_use_kernel(use_kernel):
        return kref.segment_combine_ref(vals, seg_ids, num_segments, combiner)

    vals = jnp.asarray(vals)
    squeeze = vals.ndim == 1
    if squeeze:
        vals = vals[:, None]
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    if not assume_sorted:
        order = jnp.argsort(seg_ids)
        seg_ids = seg_ids[order]
        vals = vals[order]

    e, d = vals.shape
    n_pad = _round_up(max(num_segments, 1), block_rows)
    e_pad = _round_up(max(e, 1), block_edges)
    ident = combiner.ident_for(vals.dtype)
    if e_pad != e:
        vals = jnp.concatenate(
            [vals, jnp.full((e_pad - e, d), ident, vals.dtype)], 0
        )
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full((e_pad - e,), n_pad, jnp.int32)], 0
        )
    # Out-of-range (padded/dropped) entries: push past the last row block.
    seg_ids = jnp.where(
        (seg_ids < 0) | (seg_ids >= num_segments), n_pad, seg_ids
    )

    if chunk_plan is None:
        nb = n_pad // block_rows
        bounds = jnp.searchsorted(
            seg_ids, jnp.arange(nb + 1, dtype=jnp.int32) * block_rows, side="left"
        )
        lo, hi = bounds[:-1], bounds[1:]
        cs = lo // block_edges
        ce = -((-hi) // block_edges)
        nc = jnp.where(hi > lo, ce - cs, 0).astype(jnp.int32)
        max_chunks = e_pad // block_edges  # static worst case
    else:
        cs, nc, max_chunks = chunk_plan

    out = kseg.segment_combine_pallas(
        vals,
        seg_ids,
        cs,
        nc,
        num_segments=n_pad,
        combiner=combiner,
        block_rows=block_rows,
        block_edges=block_edges,
        max_chunks=max_chunks,
        interpret=resolve_interpret(interpret),
    )[:num_segments]
    return out[:, 0] if squeeze else out


def gather_segment_combine(
    src_vals, edge_src, seg_ids, num_segments, combiner, **kw
):
    """Fused gather + segment combine (SpMV-style). Gather is left to XLA
    (it fuses with the kernel's input stream); the reduce uses the kernel."""
    vals = jnp.asarray(src_vals)[jnp.asarray(edge_src, jnp.int32)]
    return segment_combine(vals, seg_ids, num_segments, combiner, **kw)


# ---------------------------------------------------------------------------
# bucket ranks (routing data plane)
# ---------------------------------------------------------------------------


def bucket_ranks(
    keys,
    num_buckets: int,
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
    block_msgs: int = 512,
):
    """Stable arrival rank of each message within its bucket, plus the
    per-bucket occupancy — the permutation core of the one-pass routed
    exchange (see ``repro.core.routing``).

    Args:
      keys: (M,) int32 bucket per message in ``[0, num_buckets]`` where
        ``num_buckets`` is the invalid sentinel.
      num_buckets: static bucket count (the worker count W).
    Returns:
      (rank (M,) int32, counts (num_buckets,) int32).
    """
    keys = jnp.asarray(keys, jnp.int32)
    if not resolve_use_kernel(use_kernel):
        return kref.bucket_ranks_ref(keys, num_buckets)
    m = keys.shape[0]
    m_pad = _round_up(max(m, 1), block_msgs)
    if m_pad != m:
        keys = jnp.concatenate(
            [keys, jnp.full((m_pad - m,), num_buckets, jnp.int32)]
        )
    rank, counts = kbucket.bucket_ranks_pallas(
        keys,
        num_buckets=num_buckets,
        block_msgs=block_msgs,
        interpret=resolve_interpret(interpret),
    )
    return rank[:m], counts[:num_buckets]


def bucket_ranks_lanes(
    keys,
    lanes,
    num_buckets: int,
    *,
    use_kernel: Optional[bool] = None,
    interpret: Optional[bool] = None,
    block_msgs: int = 512,
):
    """Q-aware bucket ranking for the union-frontier batched data plane:
    shared stable ranks over the union key list plus the per-lane
    per-bucket membership histogram, in one sweep (the Q-aware variant of
    :func:`bucket_ranks` — see ``repro.core.routing.route_union``).

    Args:
      keys: (M,) int32 bucket per union entry in ``[0, num_buckets]``
        (``num_buckets`` = invalid sentinel).
      lanes: (M, Q) lane membership (bool/0-1) — all-False rows for
        invalid entries.
      num_buckets: static bucket count (the worker count W).
    Returns:
      (rank (M,) int32, counts (num_buckets,) int32,
       lane_counts (num_buckets, Q) int32).
    """
    keys = jnp.asarray(keys, jnp.int32)
    lanes = jnp.asarray(lanes, jnp.int32)
    if not resolve_use_kernel(use_kernel):
        return kref.bucket_ranks_lanes_ref(keys, lanes, num_buckets)
    m, q = lanes.shape
    m_pad = _round_up(max(m, 1), block_msgs)
    if m_pad != m:
        keys = jnp.concatenate(
            [keys, jnp.full((m_pad - m,), num_buckets, jnp.int32)]
        )
        lanes = jnp.concatenate(
            [lanes, jnp.zeros((m_pad - m, q), jnp.int32)]
        )
    rank, counts, lane_counts = kbucket.bucket_ranks_lanes_pallas(
        keys,
        lanes,
        num_buckets=num_buckets,
        block_msgs=block_msgs,
        interpret=resolve_interpret(interpret),
    )
    return rank[:m], counts[:num_buckets], lane_counts[:num_buckets]
