"""Jit-ready wrappers around the Pallas kernels, with plan building.

``segment_combine`` is the public entry point used by the channels: it
dispatches to the Pallas kernel (TPU target; interpret=True on CPU) or to
the pure-jnp reference depending on ``use_kernel``. The kernel path expects
sorted segment ids (the scatter-combine channel guarantees this by
construction — that is the paper's preprocessing insight).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import combiners as cb
from repro.kernels import ref as kref
from repro.kernels import segment_combine as kseg

# Flipped by tests / benchmarks; CPU default is the reference path (the
# interpret-mode kernel is a correctness vehicle, not a CPU fast path).
_USE_KERNEL_DEFAULT = False


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def build_chunk_plan(seg_ids_np, num_segments, block_rows, block_edges):
    """Host-side (numpy) plan: covering chunk range per output row block.

    Returns (chunk_start, num_chunks, max_chunks) for sorted seg_ids.
    """
    seg = np.asarray(seg_ids_np)
    nb = _round_up(num_segments, block_rows) // block_rows
    bounds = np.searchsorted(seg, np.arange(nb + 1) * block_rows, side="left")
    lo, hi = bounds[:-1], bounds[1:]
    cs = lo // block_edges
    ce = -(-hi // block_edges)  # ceil
    nc = np.where(hi > lo, ce - cs, 0).astype(np.int32)
    return cs.astype(np.int32), nc, int(nc.max(initial=0))


def segment_combine(
    vals,
    seg_ids,
    num_segments: int,
    combiner,
    *,
    use_kernel: Optional[bool] = None,
    interpret: bool = True,
    block_rows: int = 128,
    block_edges: int = 512,
    chunk_plan=None,
    assume_sorted: bool = False,
):
    """Segment reduction: out[s] = combine(vals[e] for seg_ids[e] == s).

    Entries with seg_ids >= num_segments are dropped. The kernel path
    requires sorted seg_ids (assume_sorted or it sorts internally).
    """
    combiner = cb.get(combiner)
    use_kernel = _USE_KERNEL_DEFAULT if use_kernel is None else use_kernel
    if not use_kernel:
        return kref.segment_combine_ref(vals, seg_ids, num_segments, combiner)

    vals = jnp.asarray(vals)
    squeeze = vals.ndim == 1
    if squeeze:
        vals = vals[:, None]
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    if not assume_sorted:
        order = jnp.argsort(seg_ids)
        seg_ids = seg_ids[order]
        vals = vals[order]

    e, d = vals.shape
    n_pad = _round_up(max(num_segments, 1), block_rows)
    e_pad = _round_up(max(e, 1), block_edges)
    ident = combiner.ident_for(vals.dtype)
    if e_pad != e:
        vals = jnp.concatenate(
            [vals, jnp.full((e_pad - e, d), ident, vals.dtype)], 0
        )
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full((e_pad - e,), n_pad, jnp.int32)], 0
        )
    # Out-of-range (padded/dropped) entries: push past the last row block.
    seg_ids = jnp.where(
        (seg_ids < 0) | (seg_ids >= num_segments), n_pad, seg_ids
    )

    if chunk_plan is None:
        nb = n_pad // block_rows
        bounds = jnp.searchsorted(
            seg_ids, jnp.arange(nb + 1, dtype=jnp.int32) * block_rows, side="left"
        )
        lo, hi = bounds[:-1], bounds[1:]
        cs = lo // block_edges
        ce = -((-hi) // block_edges)
        nc = jnp.where(hi > lo, ce - cs, 0).astype(jnp.int32)
        max_chunks = e_pad // block_edges  # static worst case
    else:
        cs, nc, max_chunks = chunk_plan

    out = kseg.segment_combine_pallas(
        vals,
        seg_ids,
        cs,
        nc,
        num_segments=n_pad,
        combiner=combiner,
        block_rows=block_rows,
        block_edges=block_edges,
        max_chunks=max_chunks,
        interpret=interpret,
    )[:num_segments]
    return out[:, 0] if squeeze else out


def gather_segment_combine(
    src_vals, edge_src, seg_ids, num_segments, combiner, **kw
):
    """Fused gather + segment combine (SpMV-style). Gather is left to XLA
    (it fuses with the kernel's input stream); the reduce uses the kernel."""
    vals = jnp.asarray(src_vals)[jnp.asarray(edge_src, jnp.int32)]
    return segment_combine(vals, seg_ids, num_segments, combiner, **kw)
