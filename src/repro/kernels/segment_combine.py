"""Pallas TPU kernel: sorted-segment combine (the scatter-combine hot loop).

The paper's scatter-combine channel pre-sorts edges by destination so the
per-superstep combine is a linear scan instead of hash routing. On TPU the
same preprocessing yields a *block-CSR segment reduction*:

  - destination rows are tiled into blocks of ``block_rows`` (the output
    VMEM tile),
  - the edge array (values + segment ids, already sorted by segment) is
    tiled into chunks of ``block_edges``,
  - a host-side plan maps each row block to its covering chunk range
    (scalar-prefetched, the standard block-sparse index-table pattern),
  - inside the kernel each chunk is reduced with a segmented Hillis-Steele
    scan (log2(block_edges) steps on the VPU) and the per-segment partials
    are scattered into the output tile with a one-hot ``dot_general`` on
    the MXU.

Works for sum/min/max (any Combiner with an identity): each chunk emits at
most one partial per row ("segment end", with a virtual end at the chunk
boundary), and partials combine across chunks with the same combiner.

Grid: (num_row_blocks, max_chunks_per_block); the output tile is revisited
across the chunk axis and initialized at chunk 0 — the canonical Pallas
reduction pattern. Blocks whose chunk index exceeds their chunk count are
skipped with ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import combiners as cb


def _segmented_scan(vals, seg, combiner, ident):
    """Inclusive Hillis-Steele scan of `vals` within equal-`seg` runs."""
    n = vals.shape[0]
    shift = 1
    while shift < n:
        prev_v = jnp.concatenate(
            [jnp.full((shift,) + vals.shape[1:], ident, vals.dtype), vals[:-shift]], 0
        )
        prev_s = jnp.concatenate(
            [jnp.full((shift,), -1, seg.dtype), seg[:-shift]], 0
        )
        same = (prev_s == seg)[:, None]
        vals = jnp.where(same, combiner(vals, prev_v), vals)
        shift *= 2
    return vals


def _kernel(cs_ref, nc_ref, seg_ref, vals_ref, o_ref, *, combiner, block_rows):
    i = pl.program_id(0)
    j = pl.program_id(1)
    dtype = o_ref.dtype
    ident = combiner.ident_for(dtype)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, ident)

    @pl.when(j < nc_ref[i])
    def _compute():
        row0 = i * block_rows
        seg = seg_ref[:, 0]  # (BE,) global segment id per edge
        vals = vals_ref[...]  # (BE, D)
        rel = seg - row0
        in_block = (rel >= 0) & (rel < block_rows)
        vals = jnp.where(in_block[:, None], vals, ident)

        scanned = _segmented_scan(vals, seg, combiner.fn, ident)

        # Segment ends: last element of each equal-seg run, plus a virtual
        # end at the chunk boundary (partials combine across chunks).
        nxt = jnp.concatenate([seg[1:], jnp.full((1,), -2, seg.dtype)], 0)
        is_end = (seg != nxt) & in_block

        # <=1 end per row per chunk, so a one-hot matmul extracts it exactly.
        rows = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], block_rows), 1)
        onehot = (rel[:, None] == rows) & is_end[:, None]
        safe = jnp.where(is_end[:, None], scanned, jnp.zeros_like(scanned))
        if jnp.issubdtype(dtype, jnp.integer):
            acc_t = jnp.int32
        else:
            acc_t = jnp.float32
        cand = jax.lax.dot_general(
            onehot.astype(acc_t).T,
            safe.astype(acc_t),
            (((1,), (0,)), ((), ())),
            preferred_element_type=acc_t,
        ).astype(dtype)
        has_end = onehot.any(axis=0)
        cand = jnp.where(has_end[:, None], cand, ident)
        o_ref[...] = combiner.fn(o_ref[...], cand)


def segment_combine_pallas(
    vals,
    seg_ids,
    chunk_start,
    num_chunks,
    *,
    num_segments: int,
    combiner,
    block_rows: int = 128,
    block_edges: int = 512,
    max_chunks: int,
    interpret: bool = True,
):
    """Block-CSR segment combine.

    Args:
      vals: (E_pad, D) values, sorted by segment; padded entries must have
        seg_ids >= num_segments (any value).
      seg_ids: (E_pad,) int32 sorted segment ids.
      chunk_start: (NB,) int32 first covering chunk per row block.
      num_chunks: (NB,) int32 number of covering chunks per row block.
      num_segments: output rows (padded to a multiple of block_rows).
      max_chunks: static bound on per-block chunk count (grid dim).
    Returns:
      (num_segments, D) combined values (identity for empty segments).
    """
    combiner = cb.get(combiner)
    E, D = vals.shape
    assert E % block_edges == 0, (E, block_edges)
    assert num_segments % block_rows == 0, (num_segments, block_rows)
    nb = num_segments // block_rows
    ec = E // block_edges
    grid = (nb, max(int(max_chunks), 1))

    def seg_map(i, j, cs_ref, nc_ref):
        c = cs_ref[i] + jnp.minimum(j, jnp.maximum(nc_ref[i] - 1, 0))
        return (jnp.clip(c, 0, ec - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_edges, 1), seg_map),
            pl.BlockSpec((block_edges, D), seg_map),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i, j, cs, nc: (i, 0)),
    )
    kernel = functools.partial(_kernel, combiner=combiner, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_segments, D), vals.dtype),
        interpret=interpret,
    )(
        jnp.asarray(chunk_start, jnp.int32),
        jnp.asarray(num_chunks, jnp.int32),
        jnp.asarray(seg_ids, jnp.int32)[:, None],
        vals,
    )
