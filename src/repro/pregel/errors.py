"""Structured failure taxonomy for the pregel runtime.

Every execution mode (``fused`` / ``chunked`` / ``host``) raises the same
three exception types, each carrying enough context to *recover* instead
of merely crash: the failing superstep, the offending channel name(s)
where attribution exists, and the partial :class:`~repro.pregel.runtime.
RunResult` built from the carry at the failure point. The engine's
``on_overflow="escalate"`` retry loop consumes :class:`ChannelOverflowError.
channels` to re-bucket exactly the caps that overflowed; the serve loop
quarantines the lanes named by :class:`ChannelOverflowError.qids`.

All three subclass ``RuntimeError`` so that pre-existing
``except RuntimeError`` / ``pytest.raises(RuntimeError)`` call sites keep
working unchanged.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple


class ExecutionError(RuntimeError):
    """Base class: a pregel run failed at a known superstep.

    Attributes:
      superstep: the 0-based superstep at (or by) which the failure was
        detected — for chunked mode this is the dispatch boundary where
        the device flag was observed, i.e. an upper bound.
      channels: names of the offending channels, ``()`` when the failing
        mode cannot attribute (e.g. the fused wrap latch is global).
      result: the partial RunResult reconstructed from the carry at the
        failure point (state/steps/traffic as of the failed superstep),
        or None when no carry was recoverable.
    """

    def __init__(self, message: str, *, superstep: Optional[int] = None,
                 channels: Sequence[str] = (), result=None):
        super().__init__(message)
        self.superstep = superstep
        self.channels: Tuple[str, ...] = tuple(channels)
        self.result = result


class ChannelOverflowError(ExecutionError):
    """A routed channel's per-peer slot capacity overflowed: at least one
    valid message did not fit and would have been dropped. The run's
    state past ``superstep`` is not trustworthy; re-run with larger caps
    (``Engine(on_overflow="escalate")`` does this automatically).

    ``qids`` names the offending query lanes under the batched/serving
    planes (``()`` for unbatched runs)."""

    def __init__(self, message: str, *, superstep: Optional[int] = None,
                 channels: Sequence[str] = (), result=None,
                 qids: Sequence[int] = ()):
        super().__init__(message, superstep=superstep, channels=channels,
                         result=result)
        self.qids: Tuple[int, ...] = tuple(int(q) for q in qids)


class PlanRangeError(ExecutionError):
    """A routing-plan extent would overflow the int32 id/slot space.

    Wire slots are ``owner * C + rank`` and the scatter-plan tables
    (``pack_slot`` / ``edge_src`` / ``recv_local``) are int32: at
    production ``W x C`` a slot id past ``2**31 - 1`` silently wraps into
    another worker's range and corrupts routes instead of failing. The
    bound is validated at *plan build / trace time* (it is a pure
    function of the static caps), so the failure is a structured error
    before any superstep runs — ``superstep`` is always None and
    ``channels`` names the offending plan or channel where known."""


class NonConvergenceError(ExecutionError):
    """The run exhausted ``max_steps`` without a unanimous halt vote.
    Unlike the other two, the attached ``result`` is a *complete* result
    at the step budget — raised only under ``Engine(on_nonconverged=
    "raise")``; the default merely records ``RunResult.converged=False``.
    """


class TrafficWrapError(ExecutionError):
    """An int32 traffic counter wrapped. Fused mode latches accumulator
    decrease across the whole run (no per-channel attribution); host and
    chunked modes detect a negative per-step delta and name the channel.
    Totals are unreliable — switch to ``mode="chunked"`` (host-side int64
    accumulation) or reduce per-step traffic."""


def overflow_message(superstep, channels, qids=()) -> str:
    """The uniform overflow message (kept matching the historical
    "capacity overflow" phrasing that tests and docs grep for)."""
    chan = f" in channel(s) {', '.join(channels)}" if channels else ""
    lanes = f" for queries {list(qids)}" if qids else ""
    return (
        f"channel capacity overflow{chan}{lanes} at superstep {superstep}"
        " — increase the channel capacity in the routing plan, or run "
        "under Engine(on_overflow=\"escalate\") to retry with escalated "
        "caps automatically"
    )
