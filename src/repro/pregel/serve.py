"""Continuous-batching query service — lane admission at chunk boundaries.

``Engine.run_batch`` answers a *closed* batch: Q queries enter together
and the loop runs until the last one halts, so a lane whose query
finished early rides dead in the carry until the whole batch drains.
This module opens the batch the same way continuous batching does in LLM
serving: the batched loop becomes an always-on session with a fixed lane
count, and at every chunk (dispatch) boundary lanes whose queries voted
halt are *harvested* (output extracted, per-lane steps/traffic sliced
out of the stat stream) and *refilled* from a :class:`QueryQueue` via
``VertexProgram.query_init`` — the union-frontier routed data plane
(PR 6) picks the fresh frontiers up automatically because admission just
flips the lane's ``query_live`` bit and rewrites its state slice.

The substrate is the chunked scan compiled once per session
(``repro.pregel.runtime.compile_supersteps(serve=True)``): per-lane ages
replace the shared step counter, so every tenancy is bit-identical to a
solo ``Engine.run`` of the same query — output, step count, and
per-channel traffic (the contract ``tests/test_serve.py`` pins across
chunk sizes, both ``route_batch`` strategies, and the shard_map
backend). One executable serves the whole session; refills never
re-trace.

Time has two axes: the *logical clock* counts supersteps (deterministic
— latency in supersteps is reproducible run to run) and wall time is
measured at dispatch boundaries. When every lane is idle and the next
arrival is in the future the clock fast-forwards instead of spinning.

Failure isolation (PR 9): a lane whose query overflows a channel is
**quarantined** instead of killing the session — the query is harvested
with ``status="overflow"`` (no output, the offending channel names on
``QueryRecord.channels``), the lane is recycled, and every other query
still matches its solo run bit for bit. :class:`FaultSpec` injects
deterministic failures (forced overflow / forced step-budget exhaustion
on a chosen qid at a chosen per-query step) so the isolation contract is
drillable without crafting a pathological graph; a
:class:`~repro.distributed.fault_tolerance.StragglerMonitor` watches
per-dispatch wall times and reports outlier dispatches on the result.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.fault_tolerance import StragglerMonitor
from repro.pregel import errors
from repro.pregel import runtime


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> List[int]:
    """``n`` arrival times (in supersteps) of a seeded Poisson process
    with ``rate`` expected arrivals per superstep: cumulative exponential
    gaps, floored to the superstep grid. Deterministic in (n, rate,
    seed) — the serving benchmark's workload generator."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    rng = np.random.default_rng(77 + seed)
    gaps = rng.exponential(scale=1.0 / rate, size=n)
    return np.floor(np.cumsum(gaps)).astype(np.int64).tolist()


@dataclasses.dataclass
class _Entry:
    arrival: int
    qid: int
    query: Any
    # wall timestamp at which the serving loop first saw this arrival due
    # (set once by mark_eligible; queue wait counts toward wall latency)
    wall_eligible_s: Optional[float] = None

    def __lt__(self, other):  # heap order: arrival time, then FIFO
        return (self.arrival, self.qid) < (other.arrival, other.qid)


class QueryQueue:
    """Arrival-ordered query queue for :meth:`Engine.serve`.

    Entries are ``(arrival, query)`` with ``arrival`` in supersteps on
    the session's logical clock; ties admit in push (FIFO) order, so a
    given schedule always maps to the same lane assignment — the
    determinism the serving benchmark's bit-identity check rides on.
    """

    def __init__(self):
        self._heap: List[_Entry] = []
        self._next_qid = 0

    def push(self, query: Any, arrival: int = 0) -> int:
        """Enqueue one query; returns its qid (dense, in push order)."""
        if arrival < 0:
            raise ValueError(f"arrival must be >= 0, got {arrival}")
        qid = self._next_qid
        self._next_qid += 1
        heapq.heappush(self._heap, _Entry(int(arrival), qid, query))
        return qid

    @classmethod
    def from_queries(cls, queries: Iterable[Any]) -> "QueryQueue":
        """All queries arrive at t=0 (the all-at-once schedule)."""
        q = cls()
        for query in queries:
            q.push(query)
        return q

    @classmethod
    def from_schedule(cls, pairs: Iterable[tuple]) -> "QueryQueue":
        """From ``(arrival, query)`` pairs (e.g. ``ProgramSpec.stream``)."""
        q = cls()
        for arrival, query in pairs:
            q.push(query, arrival)
        return q

    def __len__(self) -> int:
        return len(self._heap)

    def peek_query(self) -> Any:
        """The next query to be admitted (state-template source)."""
        return self._heap[0].query

    def next_arrival(self) -> Optional[int]:
        return self._heap[0].arrival if self._heap else None

    def pop_ready(self, now: int) -> Optional[_Entry]:
        """Pop the earliest entry whose arrival has passed, else None."""
        if self._heap and self._heap[0].arrival <= now:
            return heapq.heappop(self._heap)
        return None

    def mark_eligible(self, now: int, wall_s: float) -> None:
        """Stamp the wall time at which due entries became admissible
        (first boundary with ``arrival <= now``) — queue wait is part of
        a query's wall latency even before it lands in a lane."""
        for e in self._heap:
            if e.arrival <= now and e.wall_eligible_s is None:
                e.wall_eligible_s = wall_s


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault injection for a serving session.

    Fires at the first chunk boundary at which query ``qid`` has run at
    least ``at_step`` supersteps *of its own tenancy* (per-query steps,
    not the session clock — the same axis a solo run counts).

    kind="overflow": the lane is treated exactly as if a channel
    reported capacity overflow at that boundary (quarantined or raised
    per ``on_fault``). kind="exhaust": the lane is force-harvested as if
    its step budget ran out (partial output extracted, ``halted=False``,
    ``status="exhausted"``). A fault against a query that halts before
    ``at_step`` never fires.
    """

    qid: int
    at_step: int
    kind: str = "overflow"

    def __post_init__(self):
        if self.kind not in ("overflow", "exhaust"):
            raise ValueError(
                f"unknown fault kind {self.kind!r} "
                "(one of ('overflow', 'exhaust'))")
        if self.at_step < 0:
            raise ValueError(f"at_step must be >= 0, got {self.at_step}")


def as_faults(faults) -> Dict[int, FaultSpec]:
    """Normalize a faults argument — FaultSpec instances or plain
    ``(qid, at_step, kind)`` tuples — into a qid-keyed dict (at most one
    fault per qid; duplicates are rejected, not silently merged)."""
    out: Dict[int, FaultSpec] = {}
    for f in (faults or ()):
        spec = f if isinstance(f, FaultSpec) else FaultSpec(*f)
        if spec.qid in out:
            raise ValueError(f"duplicate fault for qid {spec.qid}")
        out[spec.qid] = spec
    return out


@dataclasses.dataclass
class QueryRecord:
    """One served query: identity, placement, timing, and the per-tenancy
    result/accounting (counts only this occupancy of the lane — never
    inherited from the previous occupant)."""

    qid: int
    query: Any
    lane: int
    arrival: int                 # scheduled arrival (logical clock)
    admitted: int                # boundary at which it entered its lane
    finished: int = -1           # boundary at which it was harvested
    steps: int = 0               # supersteps it actually ran
    halted: bool = False         # False = harvested on the step budget
    output: Any = None
    bytes_by_channel: Dict[str, int] = dataclasses.field(default_factory=dict)
    msgs_by_channel: Dict[str, int] = dataclasses.field(default_factory=dict)
    wall_eligible_s: float = 0.0
    wall_admitted_s: float = 0.0
    wall_finished_s: float = 0.0
    # failure disposition: "ok" (voted halt), "exhausted" (step budget),
    # "overflow" (channel capacity — quarantined, no output)
    status: str = "ok"
    injected: bool = False       # failure came from a FaultSpec drill
    channels: Tuple[str, ...] = ()   # overflowed channels, if any

    @property
    def failed(self) -> bool:
        return self.status == "overflow"

    @property
    def latency_steps(self) -> int:
        """Arrival-to-harvest latency on the logical clock (supersteps,
        including queue wait and chunk-boundary quantization)."""
        return self.finished - self.arrival

    @property
    def latency_wall_s(self) -> float:
        return self.wall_finished_s - self.wall_eligible_s

    @property
    def total_bytes(self) -> int:
        return int(sum(self.bytes_by_channel.values()))

    @property
    def total_msgs(self) -> int:
        return int(sum(self.msgs_by_channel.values()))


@dataclasses.dataclass
class ServeResult:
    """One serving session: per-query records plus session aggregates."""

    program: str
    records: List[QueryRecord]
    num_lanes: int
    chunk_size: int
    max_steps: int
    supersteps: int              # supersteps actually executed
    clock: int                   # final logical clock (incl. idle jumps)
    dispatches: int
    wall_time_s: float
    bytes_by_channel: Dict[str, int]
    msgs_by_channel: Dict[str, int]
    route_batch: str = ""
    # engine/session stamps (repro.pregel.engine.Engine.serve)
    cache_hit: bool = False
    compile_time_s: float = 0.0
    engine_compiles: int = 0
    engine_cache_hits: int = 0
    # the planned configuration the serving loop compiled under
    # (repro.plan.Plan; data-plane knobs only — the serve substrate pins
    # mode/chunk itself)
    plan: Any = None
    # dispatch indices whose wall time the StragglerMonitor flagged as
    # outliers (> threshold x rolling median), plus the session median
    straggler_dispatches: List[int] = dataclasses.field(default_factory=list)
    dispatch_median_s: float = 0.0

    @property
    def outputs(self) -> List[Any]:
        return [r.output for r in self.records]

    @property
    def num_queries(self) -> int:
        return len(self.records)

    @property
    def failed_qids(self) -> List[int]:
        """qids quarantined on channel overflow (real or injected)."""
        return [r.qid for r in self.records if r.failed]

    @property
    def num_failed(self) -> int:
        return len(self.failed_qids)

    @property
    def total_bytes(self) -> int:
        return int(sum(self.bytes_by_channel.values()))

    @property
    def total_msgs(self) -> int:
        return int(sum(self.msgs_by_channel.values()))

    @property
    def queries_per_s(self) -> float:
        return self.num_queries / self.wall_time_s if self.wall_time_s else 0.0

    def latency_summary(self) -> Dict[str, float]:
        """p50/p99/mean latency in supersteps (deterministic) and wall
        seconds — the numbers ``BENCH_serving.json`` reports."""
        if not self.records:
            return {k: 0.0 for k in (
                "p50_steps", "p99_steps", "mean_steps",
                "p50_wall_s", "p99_wall_s", "mean_wall_s")}
        steps = np.array([r.latency_steps for r in self.records], np.float64)
        wall = np.array([r.latency_wall_s for r in self.records], np.float64)
        return {
            "p50_steps": float(np.percentile(steps, 50)),
            "p99_steps": float(np.percentile(steps, 99)),
            "mean_steps": float(steps.mean()),
            "p50_wall_s": float(np.percentile(wall, 50)),
            "p99_wall_s": float(np.percentile(wall, 99)),
            "mean_wall_s": float(wall.mean()),
        }


def as_queue(requests) -> QueryQueue:
    """A QueryQueue passes through; any other iterable is an
    all-at-once batch of plain query values (arrival 0). Build a
    :meth:`QueryQueue.from_schedule` explicitly for timed arrivals."""
    if isinstance(requests, QueryQueue):
        return requests
    return QueryQueue.from_queries(requests)


def serve_loop(exe, prog, pg, state0, queue: QueryQueue, num_lanes: int,
               chunk_size: int, max_steps: int, check_overflow: bool,
               faults: Optional[Sequence] = None,
               on_fault: str = "quarantine") -> ServeResult:
    """Drive one serving session over a compiled serve executable.

    The boundary protocol, in order: (1) admit — pop due arrivals into
    free lanes, writing ``query_init`` state into the lane slice and
    clearing its age/halt/overflow; (2) if every lane is idle,
    fast-forward the clock to the next arrival (or finish); (3) dispatch
    one chunk; (4) account the chunk's per-lane steps/traffic to each
    lane's *current* occupant; (5) apply due fault injections and
    quarantine overflowed lanes (or raise, per ``on_fault``);
    (6) harvest lanes whose query halted or exhausted its step budget.
    Unoccupied lanes stay marked halted, so they are dead end to end —
    frozen state, zero traffic, masked out of the union route pass.

    Quarantine never contaminates survivors: lane state slices are
    independent, a dead lane is masked out of the route pass, and
    admission rewrites the whole slice — so the refilled lane and every
    healthy lane stay bit-identical to their solo runs.
    """
    graph = runtime.scrub_graph(pg)
    L = num_lanes
    fault_by_qid = as_faults(faults)
    state = state0
    age = np.zeros(L, np.int32)
    halted = np.ones(L, bool)          # all lanes start unoccupied
    overflow = np.zeros(L, bool)
    occupant: List[Optional[QueryRecord]] = [None] * L
    records: List[QueryRecord] = []
    sess_bytes: Dict[str, int] = {}
    sess_msgs: Dict[str, int] = {}
    monitor = StragglerMonitor()
    stragglers: List[int] = []
    clock = 0
    executed = 0
    dispatches = 0
    t0 = time.perf_counter()
    now = lambda: time.perf_counter() - t0

    while True:
        queue.mark_eligible(clock, now())
        # --- admission: FIFO by (arrival, qid) into the lowest free lane
        for lane in range(L):
            if occupant[lane] is not None:
                continue
            entry = queue.pop_ready(clock)
            if entry is None:
                break
            qstate = prog.query_init(pg, entry.query)
            state = jax.tree_util.tree_map(
                lambda leaf, new, _l=lane: leaf.at[:, _l].set(new),
                state, qstate)
            age[lane] = 0
            halted[lane] = False
            overflow[lane] = False
            occupant[lane] = QueryRecord(
                qid=entry.qid, query=entry.query, lane=lane,
                arrival=entry.arrival, admitted=clock,
                wall_eligible_s=(entry.wall_eligible_s
                                 if entry.wall_eligible_s is not None
                                 else now()),
                wall_admitted_s=now())

        if all(r is None for r in occupant):
            nxt = queue.next_arrival()
            if nxt is None:
                break               # queue drained, lanes empty: done
            clock = max(clock, nxt)  # idle — jump to the next arrival
            continue

        # --- one chunk: up to chunk_size supersteps, all live lanes
        t_disp = time.perf_counter()
        state, age_j, halted_j, overflow_j, d_steps, db, dm, dovf = \
            exe.serve_chunk(graph, state, age, halted, overflow)
        jax.block_until_ready(state)
        if monitor.record(dispatches, time.perf_counter() - t_disp):
            stragglers.append(dispatches)
        dispatches += 1
        # host-side writable copies: admission/harvest mutate them in place
        age = np.array(age_j)
        halted = np.array(halted_j)
        overflow = np.array(overflow_j)
        d_steps = np.asarray(d_steps).astype(np.int64)
        steps_run = int(d_steps.max()) if L else 0
        clock += steps_run
        executed += steps_run

        # --- per-tenancy accounting: this chunk's stats belong to the
        # lanes' current occupants (admission only happens at boundaries,
        # so a chunk is never split across tenancies)
        occupied = [l for l in range(L) if occupant[l] is not None]
        for acc, per_lane, delta in ((sess_bytes, "bytes_by_channel", db),
                                     (sess_msgs, "msgs_by_channel", dm)):
            for name, v in delta.items():
                row = runtime._host_q(v, L)
                acc[name] = acc.get(name, 0) + int(row.sum())
                for lane in occupied:
                    d = getattr(occupant[lane], per_lane)
                    d[name] = d.get(name, 0) + int(row[lane])
        for lane in occupied:
            occupant[lane].steps += int(d_steps[lane])

        # --- fault injection: force failures due at this boundary
        for lane in occupied:
            rec = occupant[lane]
            spec = fault_by_qid.get(rec.qid)
            if (spec is not None and spec.kind == "overflow"
                    and not rec.injected and rec.steps >= spec.at_step):
                overflow[lane] = True
                rec.injected = True

        # --- quarantine (or raise) lanes that overflowed a channel
        ovf_lanes = [l for l in occupied
                     if overflow[l]
                     and (check_overflow or occupant[l].injected)]
        if ovf_lanes:
            chan_flags = {name: runtime._host_q_flag(v, L)
                          for name, v in dovf.items()}
            if on_fault == "raise":
                bad = [occupant[l].qid for l in ovf_lanes]
                chans = sorted(
                    n for n, row in chan_flags.items()
                    if any(row[l] for l in ovf_lanes))
                raise errors.ChannelOverflowError(
                    errors.overflow_message(clock, chans, qids=bad),
                    superstep=clock, channels=chans, qids=bad)
            for lane in ovf_lanes:
                rec = occupant[lane]
                rec.status = "overflow"
                rec.channels = tuple(sorted(
                    n for n, row in chan_flags.items() if row[lane]))
                rec.output = None
                rec.halted = False
                rec.finished = clock
                rec.wall_finished_s = now()
                records.append(rec)
                occupant[lane] = None
                halted[lane] = True   # dead until refilled (state slice
                overflow[lane] = False  # is rewritten on admission)

        # --- harvest: lanes whose query halted or ran out of budget
        # (or whose FaultSpec exhausts it early)
        for lane in occupied:
            rec = occupant[lane]
            if rec is None:
                continue              # quarantined above
            spec = fault_by_qid.get(rec.qid)
            force = (spec is not None and spec.kind == "exhaust"
                     and rec.steps >= spec.at_step)
            if not (halted[lane] or age[lane] >= max_steps or force):
                continue
            lane_state = jax.tree_util.tree_map(
                lambda leaf, _l=lane: leaf[:, _l], state)
            rec.output = prog.extract(pg, lane_state)
            rec.halted = bool(halted[lane])
            rec.status = "ok" if rec.halted else "exhausted"
            rec.injected = rec.injected or (force and not rec.halted)
            rec.finished = clock
            rec.wall_finished_s = now()
            records.append(rec)
            occupant[lane] = None
            halted[lane] = True      # lane is dead until refilled

    records.sort(key=lambda r: r.qid)
    return ServeResult(
        program=prog.name,
        records=records,
        num_lanes=L,
        chunk_size=chunk_size,
        max_steps=max_steps,
        supersteps=executed,
        clock=clock,
        dispatches=dispatches,
        wall_time_s=time.perf_counter() - t0,
        bytes_by_channel=sess_bytes,
        msgs_by_channel=sess_msgs,
        straggler_dispatches=stragglers,
        dispatch_median_s=monitor.median,
    )
