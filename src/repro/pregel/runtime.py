"""The worker runtime (paper Fig. 4), SPMD-style.

A superstep is a jitted function mapped over the worker axis; channels
inside it communicate with axis-name collectives. Two interchangeable
backends execute the same step code:

  - ``vmap``: W logical workers on one device (tests/benchmarks on CPU);
  - ``shard_map``: W shards on a real mesh (the deployment path).

Voting-to-halt: the step function returns a local halt vote; the runtime
ANDs votes across workers (psum) and stops the host loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregator
from repro.core.channel import ChannelContext
from repro.graph.pgraph import PartitionedGraph

AXIS = "workers"


@dataclasses.dataclass
class RunResult:
    state: Any
    steps: int
    halted: bool
    bytes_by_channel: Dict[str, int]
    msgs_by_channel: Dict[str, int]
    wall_time_s: float
    step_times_s: list

    @property
    def total_bytes(self) -> int:
        return int(sum(self.bytes_by_channel.values()))

    @property
    def total_msgs(self) -> int:
        return int(sum(self.msgs_by_channel.values()))


def run_supersteps(
    graph: PartitionedGraph,
    step_fn: Callable,
    state0: Any,
    max_steps: int = 10_000,
    backend: str = "vmap",
    mesh: Optional[jax.sharding.Mesh] = None,
    axis: str = AXIS,
    check_overflow: bool = True,
) -> RunResult:
    """Run `step_fn(ctx, graph_shard, state_shard, step)` to halt.

    state0: pytree with per-vertex leaves of shape (W, n_loc, ...).
    step_fn returns (new_state, halt_local_bool) and may also return a
    third element `overflow` (bool) which the runtime surfaces as an error.
    """
    W, n_loc = graph.num_workers, graph.n_loc

    def shard_step(g_shard, state_shard, step_idx):
        ctx = ChannelContext(axis, W, n_loc)
        out = step_fn(ctx, g_shard, state_shard, step_idx)
        if len(out) == 3:
            new_state, halt, overflow = out
        else:
            new_state, halt = out
            overflow = jnp.asarray(False)
        halt_all = aggregator.all_halted(ctx, halt)
        overflow_any = jax.lax.psum(jnp.asarray(overflow, jnp.int32), axis) > 0
        nbytes, nmsgs = ctx.stats()
        return new_state, halt_all, overflow_any, nbytes, nmsgs

    if backend == "vmap":
        mapped = jax.vmap(shard_step, in_axes=(0, 0, None), axis_name=axis)

        @jax.jit
        def one_step(state, step_idx):
            return mapped(graph, state, step_idx)

    elif backend == "shard_map":
        assert mesh is not None
        P = jax.sharding.PartitionSpec
        mapped = jax.shard_map(
            shard_step,
            mesh=mesh,
            in_specs=(P(axis), P(axis), P()),
            out_specs=(P(axis), P(), P(), P(), P()),
            check_vma=False,
        )

        @jax.jit
        def one_step(state, step_idx):
            return mapped(graph, state, step_idx)

    else:
        raise ValueError(backend)

    bytes_acc: Dict[str, int] = {}
    msgs_acc: Dict[str, int] = {}
    state = state0
    halted = False
    t0 = time.perf_counter()
    step_times = []
    for step in range(max_steps):
        ts = time.perf_counter()
        state, halt_all, overflow, nbytes, nmsgs = one_step(
            state, jnp.asarray(step, jnp.int32)
        )
        jax.block_until_ready(state)
        step_times.append(time.perf_counter() - ts)
        if check_overflow and bool(np.asarray(overflow).reshape(-1)[0]):
            raise RuntimeError(
                f"channel capacity overflow at superstep {step} — "
                "increase the channel capacity in the routing plan"
            )
        for k, v in nbytes.items():
            bytes_acc[k] = bytes_acc.get(k, 0) + int(np.asarray(v).sum())
        for k, v in nmsgs.items():
            msgs_acc[k] = msgs_acc.get(k, 0) + int(np.asarray(v).sum())
        if bool(np.asarray(halt_all).reshape(-1)[0]):
            halted = True
            break
    wall = time.perf_counter() - t0
    return RunResult(
        state=state,
        steps=step + 1,
        halted=halted,
        bytes_by_channel=bytes_acc,
        msgs_by_channel=msgs_acc,
        wall_time_s=wall,
        step_times_s=step_times,
    )
