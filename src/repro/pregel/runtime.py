"""The worker runtime (paper Fig. 4), SPMD-style.

A superstep is a jitted function mapped over the worker axis; channels
inside it communicate with axis-name collectives. Two interchangeable
backends execute the same step code:

  - ``vmap``: W logical workers on one device (tests/benchmarks on CPU);
  - ``shard_map``: W shards on a real mesh (the deployment path).

Orthogonally, three *execution modes* drive the superstep loop:

  - ``fused`` (default): the whole loop runs on device inside a single
    ``jax.lax.while_loop`` dispatch — halt vote, overflow latch, step
    counter and per-channel traffic all live in the loop carry. One
    host→device round-trip per *run* instead of per *superstep*.
  - ``chunked``: ``jax.lax.scan`` over ``chunk_size`` supersteps per
    dispatch; control returns to the host at chunk boundaries for stat
    streaming (int64-safe host accumulation) and max-step enforcement.
  - ``host``: the legacy Python loop — one jitted dispatch plus a
    blocking device→host readback per superstep. Kept as the baseline
    the fusion benchmark measures against.

The fused/chunked carries need a fixed-shape stats pytree, so the runtime
performs a one-time dry trace (``jax.eval_shape`` — no compute) of the
mapped step to discover the ``ChannelRegistry``: the set of channel names
and their per-step stat shapes. Programs that declare their channels
explicitly via ``channels=(...)`` skip the dry trace entirely — the
declaration *is* the registry, and ``ChannelContext.add_traffic``
validates it lazily (a channel missing from the declaration raises the
first time the step is traced for compilation).

Compilation is split from execution: :func:`compile_supersteps` builds a
:class:`CompiledSupersteps` whose executable takes the *graph as an
argument* (not a closure constant), so one compile can be replayed
across runs and across graphs with an identical shape signature — the
contract ``repro.pregel.engine.Engine`` builds its compile cache on.
:func:`run_supersteps` remains the one-shot convenience (compile, then
execute once).

Voting-to-halt: the step function returns a local halt vote; the runtime
ANDs votes across workers (psum). In fused/chunked mode the AND result
feeds the loop condition on device; in host mode it is pulled back and
checked in Python.

Batched query plane (``num_queries=Q``): the *same* step function is
vmapped over a query axis **inside** the worker mapping — state leaves
carry ``(W, Q, n_loc, ...)``, one compiled loop advances all Q query
instances (e.g. Q SSSP sources) per superstep. Halting is per query: a
``(Q,)`` halted vector lives in the carry, queries that voted halt have
their state frozen and their traffic masked to zero from the next step
on (so per-query steps/bytes/msgs are bit-identical to Q independent
runs), and the loop exits when every query has voted halt. Per-query
step counts and per-query per-channel traffic come back on the
``RunResult`` (``query_steps`` / ``query_bytes`` / ``query_msgs``).
``repro.pregel.engine.Engine.run_batch`` is the session API on top.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregator
from repro.core import compose
from repro.core import routing
from repro.core.channel import ChannelContext, ChannelRegistry, key_under
from repro.graph.pgraph import PartitionedGraph
from repro.kernels import ops as kops
from repro.pregel import errors

AXIS = "workers"


@dataclasses.dataclass
class RunResult:
    state: Any
    steps: int
    halted: bool
    bytes_by_channel: Dict[str, int]
    msgs_by_channel: Dict[str, int]
    wall_time_s: float
    step_times_s: list
    # Execution metadata (new fields default so callers constructing the
    # seed-era 7-tuple keep working).
    mode: str = "host"
    dispatches: int = 0
    compile_time_s: float = 0.0
    # Host time spent *driving* the run — dispatch enqueues, flag/stat
    # readbacks and Python bookkeeping — excluding device waits. This is
    # the per-superstep cost the fused modes amortize to once per dispatch.
    host_overhead_s: float = 0.0
    # Engine/session metadata (repro.pregel.engine): which VertexProgram
    # produced this run, its extracted output, and the state of the
    # engine's compile cache at run time. Plain run_supersteps calls leave
    # these at their defaults.
    program: str = ""
    output: Any = None
    cache_hit: bool = False
    engine_compiles: int = 0
    engine_cache_hits: int = 0
    # Data-plane configuration the loop was compiled with (resolved —
    # benchmarks report exactly which path ran): Pallas kernels vs the
    # jnp reference, the routed-exchange implementation, and (batched
    # runs) the query-batching strategy for routed channels.
    use_kernel: bool = False
    route_impl: str = ""
    route_batch: str = ""
    # The full planned configuration the Engine compiled under (a
    # repro.plan.Plan — knobs, source, fingerprint, decision records;
    # JSON via plan.to_json()). None for plain run_supersteps calls.
    plan: Any = None
    # Batched-query metadata (num_queries > 0 iff the loop carried a
    # query axis). The per-query arrays are host numpy, length Q;
    # bytes_by_channel/msgs_by_channel hold the across-query totals.
    # ``outputs`` is the per-query extracted answer list (Engine.run_batch).
    num_queries: int = 0
    query_steps: Any = None            # (Q,) int64
    query_halted: Any = None           # (Q,) bool
    query_bytes_by_channel: Optional[Dict[str, Any]] = None  # name->(Q,)
    query_msgs_by_channel: Optional[Dict[str, Any]] = None   # name->(Q,)
    outputs: Any = None
    # Pad-lane audit (batched runs): bucket-padding lanes start halted
    # (``query_live=False`` end to end), so they must never step, occupy
    # wire slots, or be charged. These aggregates over the pad lanes are
    # the evidence — all three stay zero (pinned by tests/test_batch.py).
    num_pad_lanes: int = 0
    pad_steps: int = 0
    pad_bytes: int = 0
    pad_msgs: int = 0
    # Resilience layer (repro.pregel.errors / Engine on_overflow):
    # converged distinguishes a unanimous halt vote from max_steps
    # exhaustion (for batched runs: every real lane voted halt);
    # overflow_by_channel is the per-channel overflow attribution (name ->
    # bool, or name -> (Q,) bool for batched runs); recovery is the
    # engine's escalation decision log (list of dicts, None when the run
    # needed no recovery); resumed_from is the checkpointed superstep a
    # chunked run was resumed at (0 = ran from scratch).
    converged: bool = False
    overflow_by_channel: Optional[Dict[str, Any]] = None
    recovery: Any = None
    resumed_from: int = 0

    @property
    def total_bytes(self) -> int:
        return int(sum(self.bytes_by_channel.values()))

    @property
    def total_msgs(self) -> int:
        return int(sum(self.msgs_by_channel.values()))

    # -- namespaced (composed-channel) attribution helpers ----------------

    def bytes_under(self, prefix: str) -> int:
        """Total bytes accounted under a namespaced key prefix."""
        return int(sum(v for k, v in self.bytes_by_channel.items()
                       if key_under(k, prefix)))

    def msgs_under(self, prefix: str) -> int:
        """Total messages accounted under a namespaced key prefix."""
        return int(sum(v for k, v in self.msgs_by_channel.items()
                       if key_under(k, prefix)))

    # -- per-query (batched run) views ------------------------------------

    def query_bytes(self, q: int) -> Dict[str, int]:
        """Per-channel byte totals attributed to query ``q``."""
        return {k: int(v[q]) for k, v in self.query_bytes_by_channel.items()}

    def query_msgs(self, q: int) -> Dict[str, int]:
        """Per-channel message totals attributed to query ``q``."""
        return {k: int(v[q]) for k, v in self.query_msgs_by_channel.items()}


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (jax.shard_map vs experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental import shard_map as _sm

    return _sm.shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def _scalar(x):
    """() view of a flag that may be per-worker replicated ((W,) or ())."""
    return jnp.asarray(x).reshape(-1)[0] if jnp.ndim(x) else jnp.asarray(x)


def _host_int(v) -> int:
    """Device stat leaf -> exact host int (int64-safe accumulation)."""
    return int(np.asarray(v).astype(np.int64).sum())


def scrub_graph(graph: PartitionedGraph) -> PartitionedGraph:
    """Drop the host-only static fields that carry per-graph identity but
    never enter traced code: the graph ``name``/``new_of_old`` and the
    plans' exact-count reporting statics (``total_edges`` /
    ``remote_entries`` — two graphs whose counts differ inside one
    power-of-two cap bucket must still share a treedef). Two graphs with
    identical shapes/caps scrub to identical pytree treedefs, which is
    what lets one compiled executable serve both."""

    def scatter(plan):
        # mirrored_edges is an exact count (reporting only); hub_cap and
        # route_cap are *shape* statics and stay — they change compiled
        # buffer extents, so they must split the compile cache.
        return plan if plan is None else dataclasses.replace(
            plan, remote_entries=0, total_edges=0, mirrored_edges=0)

    def prop(plan):
        return plan if plan is None else dataclasses.replace(
            plan, cut=scatter(plan.cut))

    return dataclasses.replace(
        graph, name="", new_of_old=None,
        scatter_out=scatter(graph.scatter_out),
        scatter_in=scatter(graph.scatter_in),
        prop_out=prop(graph.prop_out),
        prop_in=prop(graph.prop_in),
    )


def graph_signature(graph: PartitionedGraph):
    """Hashable shape signature of a graph: the scrubbed pytree treedef
    (all static caps/metadata) plus every leaf's shape and dtype. Equal
    signatures <=> a compiled executable is reusable (and numerically
    identical, since *all* remaining statics are part of the treedef)."""
    leaves, treedef = jax.tree_util.tree_flatten(scrub_graph(graph))
    return (treedef,
            tuple((tuple(l.shape), str(jnp.dtype(l.dtype))) for l in leaves))


def state_signature(state) -> Tuple:
    """Hashable treedef+avals signature of a state pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return (treedef,
            tuple((tuple(jnp.shape(l)), str(jnp.result_type(l)))
                  for l in leaves))


@dataclasses.dataclass
class CompiledSupersteps:
    """A compiled superstep loop, reusable across runs.

    The wrapped executable was AOT-compiled (``jit(...).lower().compile()``)
    with the graph as an argument, so :meth:`execute` may be called many
    times — with the original graph or any graph whose
    :func:`graph_signature` matches — without ever re-tracing.
    ``repro.pregel.engine.Engine`` caches these per (program, shape, mode).
    """

    mode: str
    max_steps: int
    check_overflow: bool
    chunk_size: int
    registry: Optional[ChannelRegistry]
    compile_time_s: float
    _fn: Callable
    # resolved data-plane configuration baked into the compiled loop
    use_kernel: bool = False
    route_impl: str = "bucket"
    route_batch: str = "union"
    dense_threshold: float = 0.1
    # query-axis width the loop was lowered with (None = unbatched)
    num_queries: Optional[int] = None
    # serving substrate (compile_supersteps(serve=True)): the chunked
    # executable carries per-lane ages instead of a global step index so
    # lanes can be swapped at chunk boundaries (Engine.serve)
    serve: bool = False

    def serve_chunk(self, graph: PartitionedGraph, state, age, halted,
                    overflow):
        """One serving dispatch: advance every live lane by up to
        ``chunk_size`` supersteps. Carry: per-lane ``age`` (steps since
        admission — the step index each lane's step function sees),
        ``halted`` (lane voted halt OR lane unoccupied), ``overflow``.
        Returns ``(state, age, halted, overflow, d_steps, db, dm, dovf)``
        with ``d_steps`` the per-lane steps advanced this chunk, db/dm
        the per-step stat stream and dovf the per-step per-channel
        overflow flags (per-lane attribution for quarantine). The host (``repro.pregel.serve``) harvests
        finished lanes and refills them between calls — this method never
        re-traces, one executable serves the whole session."""
        if not self.serve:
            raise ValueError("not a serving executable "
                             "(compile_supersteps(serve=True))")
        return self._fn(scrub_graph(graph), state, age, halted, overflow)

    def execute(self, graph: PartitionedGraph, state0: Any,
                num_real_queries: Optional[int] = None,
                checkpoint_every: Optional[int] = None,
                checkpoint_cb: Optional[Callable] = None,
                resume: Optional[dict] = None) -> RunResult:
        """One run. ``compile_time_s`` on the result is 0 — the caller
        that paid the compile stamps it (run_supersteps / Engine miss).

        num_real_queries: for a batched loop, how many leading query
        lanes are real (the rest are bucket padding) — every per-query
        view, total, and overflow report covers only those lanes.

        checkpoint_every/checkpoint_cb/resume: chunked-mode (unbatched)
        checkpointing — at the first dispatch boundary at or past every
        ``checkpoint_every`` supersteps, ``checkpoint_cb`` receives a
        host-side carry snapshot (step/state/accumulated traffic);
        ``resume`` restarts the loop from such a snapshot, bit-identical
        to the uninterrupted run (see ``repro.pregel.checkpoint``)."""
        # the executable was lowered against the scrubbed treedef, so any
        # same-signature graph replays (name/new_of_old identity dropped)
        graph = scrub_graph(graph)
        if self.serve:
            raise ValueError("serving executables are driven chunk by "
                             "chunk (serve_chunk / Engine.serve)")
        wants_ckpt = (checkpoint_every is not None or checkpoint_cb is not None
                      or resume is not None)
        if wants_ckpt and (self.mode != "chunked"
                           or self.num_queries is not None):
            raise ValueError(
                "checkpoint/resume needs the unbatched chunked substrate — "
                f"this executable is mode={self.mode!r}, num_queries="
                f"{self.num_queries}. Compile with mode='chunked' "
                "(Engine(mode='chunked')) to checkpoint at dispatch "
                "boundaries.")
        if self.num_queries is not None:
            res = _exec_batched(self._fn, graph, state0, self.mode,
                                self.max_steps, self.check_overflow,
                                self.num_queries,
                                num_real_queries or self.num_queries)
        elif self.mode == "host":
            res = _exec_host(self._fn, graph, state0, self.max_steps,
                             self.check_overflow)
        elif self.mode == "fused":
            res = _exec_fused(self._fn, graph, state0, self.check_overflow)
        else:
            res = _exec_chunked(self._fn, graph, state0, self.max_steps,
                                self.check_overflow,
                                checkpoint_every=checkpoint_every,
                                checkpoint_cb=checkpoint_cb, resume=resume)
        res.use_kernel = self.use_kernel
        res.route_impl = self.route_impl
        res.route_batch = self.route_batch if self.num_queries else ""
        return res


def compile_supersteps(
    graph: PartitionedGraph,
    step_fn: Callable,
    state0: Any,
    max_steps: int = 10_000,
    backend: str = "vmap",
    mesh: Optional[jax.sharding.Mesh] = None,
    axis: str = AXIS,
    check_overflow: bool = True,
    mode: Optional[str] = None,
    chunk_size: int = 64,
    channels: Optional[Any] = None,
    use_kernel: Optional[bool] = None,
    route_impl: Optional[str] = None,
    route_batch: Optional[str] = None,
    dense_threshold: Optional[float] = None,
    num_queries: Optional[int] = None,
    serve: bool = False,
    cap_scales: Optional[Dict[str, float]] = None,
) -> CompiledSupersteps:
    """Compile `step_fn(ctx, graph_shard, state_shard, step)` for a graph
    shape, without running it. See :func:`run_supersteps` for semantics.

    use_kernel / route_impl pin the data-plane configuration for the
    whole compile (None = resolve from env/backend defaults, see
    ``repro.kernels.ops`` / ``repro.core.routing``); explicit per-call
    channel arguments inside the step still win.

    num_queries=Q lowers the *batched* loop: the step is vmapped over a
    query axis inside the worker mapping, ``state0`` leaves must carry
    ``(W, Q, n_loc, ...)``, and halting/step counts/traffic are tracked
    per query (see the module docstring). The step function itself is
    unchanged — it still sees one query's ``(n_loc, ...)`` shard.

    route_batch selects how *routed* channels handle the query axis in a
    batched compile: ``"union"`` (default) shares ONE union-frontier
    bucket-route pass per superstep across all live lanes
    (``repro.core.routing.route_union``), ``"lane"`` routes each lane
    independently under the vmap (the pre-union behavior). Ignored when
    num_queries is None.

    serve=True (requires num_queries and mode="chunked") lowers the
    *serving* substrate instead: lanes are independent tenancies, so the
    step index each lane sees is its own age (steps since admission, a
    ``(Q,)`` carry leaf) rather than a shared loop counter, a lane's
    step budget is ``age < max_steps``, and the executable surfaces the
    chunk-boundary carry for the host-side lane swap
    (:meth:`CompiledSupersteps.serve_chunk`, ``repro.pregel.serve``).
    """
    # lower against the scrubbed graph: the compiled treedef must not
    # capture the host-only identity statics, or execute() could only
    # ever be called with this exact graph object
    graph = scrub_graph(graph)
    W, n_loc = graph.num_workers, graph.n_loc
    if mode is None:
        mode = "fused"
    if mode not in ("fused", "chunked", "host"):
        raise ValueError(f"unknown execution mode {mode!r}")
    if serve and (num_queries is None or mode != "chunked"):
        raise ValueError(
            "serve=True needs the chunked batched substrate "
            f"(num_queries=Q, mode='chunked'); got num_queries="
            f"{num_queries}, mode={mode!r}")

    traced_names: set = set()

    def make_shard_step(registry: Optional[ChannelRegistry]):
        def shard_step(g_shard, state_shard, step_idx, qinfo=None):
            # qinfo = (lane_index (), lane_live ()) under the query vmap —
            # the per-lane scalars routed channels use to share one
            # union-frontier route pass across lanes (route_batch="union")
            if qinfo is None:
                ctx = ChannelContext(axis, W, n_loc, registry=registry,
                                     cap_scales=cap_scales or {},
                                     route_cap=graph.route_cap)
            else:
                ctx = ChannelContext(
                    axis, W, n_loc, registry=registry,
                    cap_scales=cap_scales or {},
                    query_index=qinfo[0], query_live=qinfo[1],
                    num_queries=num_queries,
                    route_cap=graph.route_cap)
            out = step_fn(ctx, g_shard, state_shard, step_idx)
            if len(out) == 3:
                new_state, halt, overflow = out
            else:
                new_state, halt = out
                overflow = jnp.asarray(False)
            halt_all = aggregator.all_halted(ctx, halt)
            overflow_any = jax.lax.psum(
                jnp.asarray(overflow, jnp.int32), axis) > 0
            traced_names.update(ctx.touched)  # host-side, at trace time
            nbytes, nmsgs = ctx.stats()
            novf = dict(ctx.stats_ovf)
            if backend == "shard_map":
                # vmap surfaces one stat scalar per worker ((W,) leaves,
                # summed host-side); shard_map's replicated out-spec would
                # surface only shard 0's local count, so reduce to the
                # global per-step total on device — same totals, either
                # backend
                psum = lambda v: jax.lax.psum(v, axis)
                nbytes = jax.tree_util.tree_map(psum, nbytes)
                nmsgs = jax.tree_util.tree_map(psum, nmsgs)
                novf = jax.tree_util.tree_map(
                    lambda v: jax.lax.psum(
                        jnp.asarray(v, jnp.int32), axis) > 0, novf)
            return new_state, halt_all, overflow_any, nbytes, nmsgs, novf

        return shard_step

    def map_shards(shard_step):
        if num_queries is not None:
            # the query axis rides INSIDE the worker mapping: each worker
            # advances all Q query instances of its shard; the axis-name
            # collectives inside the step batch transparently over Q. The
            # per-lane (index, live) scalars are batched alongside so the
            # union-frontier routed channels always see a Q-batched
            # operand (their custom_vmap rule fires on the query trace).
            # Serving compiles batch the step index too: each lane's
            # step function sees its own age, not a shared loop counter.
            step_ax = 0 if serve else None
            q_inner = jax.vmap(shard_step, in_axes=(None, 0, step_ax, 0))

            def shard_step_q(g_shard, state_shard, step_idx, live):
                qinfo = (jnp.arange(num_queries, dtype=jnp.int32),
                         jnp.asarray(live, bool))
                return q_inner(g_shard, state_shard, step_idx, qinfo)

            shard_step = shard_step_q
            worker_axes = (0, 0, None, None)
        else:
            worker_axes = (0, 0, None)
        if backend == "vmap":
            return jax.vmap(shard_step, in_axes=worker_axes, axis_name=axis)
        if backend == "shard_map":
            assert mesh is not None
            if mesh.shape[axis] != W:
                raise ValueError(
                    f"shard_map backend needs one worker per mesh device "
                    f"along {axis!r}: graph has W={W}, mesh axis size "
                    f"{mesh.shape[axis]}")
            P = jax.sharding.PartitionSpec

            def device_step(g_shard, state_shard, step_idx, *rest):
                # shard_map keeps the sharded axis as a leading size-1
                # dim; the step code (like vmap's) works on the bare
                # shard — peel it off and put it back on the state.
                # ``rest`` is the replicated (Q,) liveness vector on
                # batched compiles, empty otherwise.
                one = lambda x: x[0]
                new_state, halt, ovf, nb, nm, novf = shard_step(
                    jax.tree_util.tree_map(one, g_shard),
                    jax.tree_util.tree_map(one, state_shard),
                    step_idx,
                    *rest,
                )
                new_state = jax.tree_util.tree_map(
                    lambda x: x[None], new_state)
                return new_state, halt, ovf, nb, nm, novf

            extra = (P(),) if num_queries is not None else ()
            return _shard_map(
                device_step,
                mesh=mesh,
                in_specs=(P(axis), P(axis), P()) + extra,
                out_specs=(P(axis), P(), P(), P(), P(), P()),
            )
        raise ValueError(backend)

    # --- channel registry. A `channels=` declaration IS the registry (no
    # dry trace at all — ChannelContext.add_traffic rejects undeclared
    # names when the step is traced for compilation below). Without a
    # declaration, the fused/chunked carries still need the fixed key set,
    # so discover it with a one-time jax.eval_shape dry trace (no compute).
    # Host mode consumes open per-step dicts and needs no registry. ------
    registry = None
    resolved_kernel = kops.resolve_use_kernel(use_kernel)
    resolved_route = routing.resolve_impl(route_impl)
    resolved_batch = routing.resolve_batch(route_batch)
    resolved_thresh = compose.resolve_dense_threshold(dense_threshold)
    # the data-plane choice is baked in at trace time: every channel call
    # that did not pass an explicit argument resolves through these scopes
    with kops.use_kernel_scope(resolved_kernel), \
            routing.impl_scope(resolved_route), \
            routing.batch_scope(resolved_batch), \
            compose.dense_threshold_scope(resolved_thresh):
        if channels is not None:
            names = compose.channel_names_of(channels)
            # the mapped step's per-step stat leaf is (W,) under vmap (one
            # scalar per logical worker) and () under shard_map (replicated);
            # a query axis appends Q as the trailing dimension
            stat_shape = (W,) if backend == "vmap" else ()
            if num_queries is not None:
                stat_shape = stat_shape + (num_queries,)
            registry = ChannelRegistry.declare(sorted(names), shape=stat_shape)
        elif mode in ("fused", "chunked"):
            probe = map_shards(make_shard_step(None))
            if serve:
                step_probe = jnp.zeros((num_queries,), jnp.int32)
            else:
                step_probe = jnp.asarray(0, jnp.int32)
            probe_args = (graph, state0, step_probe)
            if num_queries is not None:
                probe_args += (jnp.ones((num_queries,), bool),)
            out_struct = jax.eval_shape(probe, *probe_args)
            _, _, _, bytes_struct, _, _ = out_struct
            registry = ChannelRegistry.from_stats_structure(bytes_struct)

        mapped = map_shards(make_shard_step(registry))
        i0 = jnp.asarray(0, jnp.int32)

        tc = time.perf_counter()
        if num_queries is not None:
            h0 = jnp.zeros((num_queries,), bool)
            if serve:
                a0 = jnp.zeros((num_queries,), jnp.int32)
                fn = (jax.jit(_make_serve_chunk(
                        mapped, registry, max_steps, check_overflow,
                        chunk_size, num_queries))
                      .lower(graph, state0, a0, h0, h0).compile())
            elif mode == "host":
                fn = (jax.jit(_make_batched_step(mapped, num_queries))
                      .lower(graph, state0, i0, h0).compile())
            elif mode == "fused":
                fn = (jax.jit(_make_batched_fused_loop(
                        mapped, registry, max_steps, check_overflow,
                        num_queries))
                      .lower(graph, state0, h0).compile())
            else:
                fn = (jax.jit(_make_batched_chunk(
                        mapped, registry, max_steps, check_overflow,
                        chunk_size, num_queries))
                      .lower(graph, state0, i0, h0, h0).compile())
        elif mode == "host":
            fn = jax.jit(mapped).lower(graph, state0, i0).compile()
        elif mode == "fused":
            fn = (
                jax.jit(_make_fused_loop(mapped, registry, max_steps,
                                         check_overflow))
                .lower(graph, state0)
                .compile()
            )
        else:
            f = jnp.zeros((), bool)
            fn = (
                jax.jit(_make_chunk(mapped, registry, max_steps,
                                    check_overflow, chunk_size))
                .lower(graph, state0, i0, f, f)
                .compile()
            )
        compile_s = time.perf_counter() - tc

    # both validation directions without a dry trace: an undeclared
    # traced channel raised from add_traffic during the AOT trace above;
    # a declared-but-never-traced channel is caught here (it would
    # otherwise report phantom zero rows forever)
    if channels is not None:
        phantom = set(registry.names) - traced_names
        if phantom:
            raise ValueError(
                f"declared channels {tuple(sorted(phantom))} were never "
                f"traced by the step function (traced: "
                f"{tuple(sorted(traced_names))}) — stale or misspelled "
                "declaration"
            )

    return CompiledSupersteps(
        mode=mode,
        max_steps=max_steps,
        check_overflow=check_overflow,
        chunk_size=chunk_size,
        registry=registry,
        compile_time_s=compile_s,
        _fn=fn,
        use_kernel=resolved_kernel,
        route_impl=resolved_route,
        route_batch=resolved_batch,
        dense_threshold=resolved_thresh,
        num_queries=num_queries,
        serve=serve,
    )


def run_supersteps(
    graph: PartitionedGraph,
    step_fn: Callable,
    state0: Any,
    max_steps: int = 10_000,
    backend: str = "vmap",
    mesh: Optional[jax.sharding.Mesh] = None,
    axis: str = AXIS,
    check_overflow: bool = True,
    mode: Optional[str] = None,
    chunk_size: int = 64,
    channels: Optional[Any] = None,
    use_kernel: Optional[bool] = None,
    route_impl: Optional[str] = None,
    route_batch: Optional[str] = None,
) -> RunResult:
    """Run `step_fn(ctx, graph_shard, state_shard, step)` to halt.

    state0: pytree with per-vertex leaves of shape (W, n_loc, ...).
    step_fn returns (new_state, halt_local_bool) and may also return a
    third element `overflow` (bool) which the runtime surfaces as an error.

    mode: "fused" (default), "chunked", or "host" — see module docstring.
    channels: optional explicit channel declaration — a sequence of
      stat-key names, a composed channel (any object with
      ``channel_names()``, e.g. ``repro.core.compose.Stacked``), or a
      mixed sequence of both. Declared programs skip the eval_shape dry
      trace; the declaration is validated lazily by
      ``ChannelContext.add_traffic`` (an undeclared channel raises while
      the step is traced for compilation).

    Compiles per call; hold a ``repro.pregel.engine.Engine`` to reuse
    compiles across runs and same-shape graphs.
    """
    exe = compile_supersteps(
        graph, step_fn, state0, max_steps=max_steps, backend=backend,
        mesh=mesh, axis=axis, check_overflow=check_overflow, mode=mode,
        chunk_size=chunk_size, channels=channels, use_kernel=use_kernel,
        route_impl=route_impl, route_batch=route_batch,
    )
    res = exe.execute(graph, state0)
    res.compile_time_s = exe.compile_time_s
    return res


# ---------------------------------------------------------------------------
# host mode: one dispatch + blocking readback per superstep (baseline)
# ---------------------------------------------------------------------------


def _exec_host(stepper, graph, state0, max_steps, check_overflow) -> RunResult:
    bytes_acc: Dict[str, int] = {}
    msgs_acc: Dict[str, int] = {}
    ovf_acc: Dict[str, bool] = {}
    state = state0
    halted = False
    t0 = time.perf_counter()
    step_times = []
    overhead = 0.0
    overflowed = False
    wrapped_keys: set = set()
    step = -1  # so max_steps=0 reports zero executed supersteps
    for step in range(max_steps):
        ts = time.perf_counter()
        state, halt_all, overflow, nbytes, nmsgs, novf = stepper(
            graph, state, jnp.asarray(step, jnp.int32)
        )
        t_enq = time.perf_counter()
        jax.block_until_ready(state)
        t_dev = time.perf_counter()
        step_times.append(t_dev - ts)
        for k, v in nbytes.items():
            d = _host_int(v)
            if d < 0:
                wrapped_keys.add(k)
            bytes_acc[k] = bytes_acc.get(k, 0) + d
        for k, v in nmsgs.items():
            d = _host_int(v)
            if d < 0:
                wrapped_keys.add(k)
            msgs_acc[k] = msgs_acc.get(k, 0) + d
        for k, v in novf.items():
            ovf_acc[k] = ovf_acc.get(k, False) or bool(np.asarray(v).any())
        halt_now = bool(np.asarray(halt_all).reshape(-1)[0])
        # dispatch enqueue plus readback/bookkeeping time: the host cost
        # of driving one step (the stepper is AOT-compiled, so step 0 is
        # an ordinary dispatch)
        overhead += t_enq - ts
        overhead += time.perf_counter() - t_dev
        if check_overflow and bool(np.asarray(overflow).reshape(-1)[0]):
            overflowed = True
            break
        if wrapped_keys:
            break
        if halt_now:
            halted = True
            break
    wall = time.perf_counter() - t0
    res = RunResult(
        state=state,
        steps=step + 1,
        halted=halted,
        bytes_by_channel=bytes_acc,
        msgs_by_channel=msgs_acc,
        wall_time_s=wall,
        step_times_s=step_times,
        mode="host",
        dispatches=step + 1,
        host_overhead_s=overhead,
        converged=halted,
        overflow_by_channel=ovf_acc,
    )
    if overflowed:
        bad = sorted(k for k, v in ovf_acc.items() if v)
        raise errors.ChannelOverflowError(
            errors.overflow_message(step, bad),
            superstep=step, channels=bad, result=res)
    if wrapped_keys:
        bad = sorted(wrapped_keys)
        raise errors.TrafficWrapError(
            f"int32 traffic counter wrapped in channel(s) {', '.join(bad)} "
            f"at superstep {step} — per-step traffic exceeds int32 range",
            superstep=step, channels=bad, result=res)
    return res


# ---------------------------------------------------------------------------
# fused mode: the entire superstep loop is one lax.while_loop dispatch
# ---------------------------------------------------------------------------


def _make_fused_loop(mapped, registry, max_steps, check_overflow):
    zeros = registry.zeros()
    flags = registry.flags()

    def loop(graph, state):
        def cond(carry):
            _, i, halted, overflow, _, _, _, _ = carry
            go = (~halted) & (i < max_steps)
            if check_overflow:
                go = go & (~overflow)
            return go

        def body(carry):
            state, i, _, overflow, nb, nm, ovf_by, wrapped = carry
            new_state, halt, ovf, db, dm, dovf = mapped(graph, state, i)
            nb2 = jax.tree_util.tree_map(jnp.add, nb, db)
            nm2 = jax.tree_util.tree_map(jnp.add, nm, dm)
            ovf_by2 = jax.tree_util.tree_map(jnp.logical_or, ovf_by, dovf)
            # per-step deltas are non-negative, so a decreasing accumulator
            # means the int32 counter wrapped — latch it for the host
            for old, new in ((nb, nb2), (nm, nm2)):
                for o, n in zip(jax.tree_util.tree_leaves(old),
                                jax.tree_util.tree_leaves(new)):
                    wrapped = wrapped | jnp.any(n < o)
            return (new_state, i + 1, _scalar(halt),
                    overflow | _scalar(ovf), nb2, nm2, ovf_by2, wrapped)

        init = (state, jnp.asarray(0, jnp.int32), jnp.zeros((), bool),
                jnp.zeros((), bool), zeros, zeros, flags,
                jnp.zeros((), bool))
        return jax.lax.while_loop(cond, body, init)

    return loop


def _exec_fused(compiled, graph, state0, check_overflow) -> RunResult:
    t0 = time.perf_counter()
    out = compiled(graph, state0)
    state, steps, halted, overflow, nb, nm, novf, wrapped = out
    t_enq = time.perf_counter()
    jax.block_until_ready(state)
    t_dev = time.perf_counter()
    wall = t_dev - t0

    steps = int(np.asarray(steps))
    halted_b = bool(np.asarray(halted))
    bytes_by = {k: _host_int(v) for k, v in nb.items()}
    msgs_by = {k: _host_int(v) for k, v in nm.items()}
    ovf_by = {k: bool(np.asarray(v).any()) for k, v in novf.items()}
    overhead = (t_enq - t0) + (time.perf_counter() - t_dev)
    res = RunResult(
        state=state,
        steps=steps,
        halted=halted_b,
        bytes_by_channel=bytes_by,
        msgs_by_channel=msgs_by,
        wall_time_s=wall,
        step_times_s=[wall],
        mode="fused",
        dispatches=1,
        host_overhead_s=overhead,
        converged=halted_b,
        overflow_by_channel=ovf_by,
    )
    if check_overflow and bool(np.asarray(overflow)):
        bad = sorted(k for k, v in ovf_by.items() if v)
        raise errors.ChannelOverflowError(
            errors.overflow_message(steps - 1, bad),
            superstep=steps - 1, channels=bad, result=res)
    if bool(np.asarray(wrapped)):
        # the fused latch is global (accumulator decreased) — no
        # per-channel attribution on device
        raise errors.TrafficWrapError(
            "per-channel traffic counters overflowed int32 inside the fused "
            "loop; bytes/msgs totals are unreliable — use mode='chunked' "
            "(exact host-side int64 accumulation) for runs this heavy",
            superstep=steps - 1, result=res)
    return res


# ---------------------------------------------------------------------------
# chunked mode: lax.scan over K supersteps per dispatch; the host streams
# per-step stats (exact int64 accumulation) at every chunk boundary
# ---------------------------------------------------------------------------


def _make_chunk(mapped, registry, max_steps, check_overflow, chunk_size):
    K = max(1, min(chunk_size, max_steps))
    zeros = registry.zeros()
    flags = registry.flags()

    def chunk(graph, state, i0, halted0, overflow0):
        def body(carry, _):
            state, i, halted, overflow = carry
            stop = halted | (i >= max_steps)
            if check_overflow:
                stop = stop | overflow

            def do(operand):
                state, i = operand
                new_state, halt, ovf, db, dm, dovf = mapped(graph, state, i)
                return ((new_state, i + 1, _scalar(halt),
                         overflow | _scalar(ovf)), (db, dm, dovf))

            def skip(operand):
                state, i = operand
                # skipped steps contribute zero traffic
                return ((state, i, halted, overflow),
                        (zeros, zeros, flags))

            return jax.lax.cond(stop, skip, do, (state, i))

        (state, i, halted, overflow), (db, dm, dovf) = jax.lax.scan(
            body, (state, i0, halted0, overflow0), None, length=K
        )
        return state, i, halted, overflow, db, dm, dovf

    return chunk


# ---------------------------------------------------------------------------
# batched query plane: one loop advances Q query instances per superstep,
# with per-query halt voting, frozen state for halted queries, and
# per-query step/traffic attribution (engine.Engine.run_batch rides this)
# ---------------------------------------------------------------------------


def _qrow(x, q: int):
    """(Q,) view of a per-query flag that may be worker-replicated
    ((W, Q) under vmap, (Q,) under shard_map)."""
    return jnp.asarray(x).reshape((-1, q))[0]


def _qmask(live, leaf):
    """Broadcast a (Q,) liveness mask against a (W, Q, ...) state leaf."""
    return live.reshape((1,) + live.shape + (1,) * (leaf.ndim - 2))


def _host_q(v, q: int) -> np.ndarray:
    """Stat leaf with trailing query axis -> (Q,) int64 per-query totals
    (sums any leading worker/chunk axes)."""
    return np.asarray(v).astype(np.int64).reshape((-1, q)).sum(axis=0)


def _make_batched_step(mapped, q: int):
    """One batched superstep with the per-query bookkeeping folded in:
    halted queries keep their state bit-for-bit (their lanes still
    compute, the result is discarded) and contribute zero traffic and no
    overflow. Shared by all three batched modes — host compiles it
    directly, fused/chunked call it from their loop bodies."""

    def bstep(graph, state, i, halted):
        live = ~halted
        new_state, halt, ovf, db, dm, dovf = mapped(graph, state, i, live)
        new_state = jax.tree_util.tree_map(
            lambda n, o: jnp.where(_qmask(live, n), n, o), new_state, state)
        # stat leaves have the query axis last ((W, Q) / (Q,)) — the
        # (Q,) mask broadcasts; the halting step itself still charges
        # (live is the PRE-step vote, matching Q independent runs)
        db = jax.tree_util.tree_map(lambda d: jnp.where(live, d, 0), db)
        dm = jax.tree_util.tree_map(lambda d: jnp.where(live, d, 0), dm)
        dovf = jax.tree_util.tree_map(
            lambda d: jnp.where(live, d, False), dovf)
        return (new_state, halted | _qrow(halt, q),
                _qrow(ovf, q) & live, db, dm, dovf)

    return bstep


def _make_batched_fused_loop(mapped, registry, max_steps, check_overflow, q):
    zeros = registry.zeros()
    flags = registry.flags()
    bstep = _make_batched_step(mapped, q)

    # halted0 is an argument (not a constant) so bucket-padding lanes can
    # start halted: a pad lane then never steps, never reaches the union
    # route pass (query_live=False end to end), and is never charged
    def loop(graph, state, halted0):
        def cond(carry):
            _, i, halted, overflow, _, _, _, _, _ = carry
            go = jnp.any(~halted) & (i < max_steps)
            if check_overflow:
                go = go & ~jnp.any(overflow)
            return go

        def body(carry):
            state, i, halted, overflow, steps_q, nb, nm, ovf_by, wrapped = (
                carry)
            new_state, halted2, ovf_q, db, dm, dovf = bstep(
                graph, state, i, halted)
            nb2 = jax.tree_util.tree_map(jnp.add, nb, db)
            nm2 = jax.tree_util.tree_map(jnp.add, nm, dm)
            ovf_by2 = jax.tree_util.tree_map(jnp.logical_or, ovf_by, dovf)
            for old, new in ((nb, nb2), (nm, nm2)):
                for o, n in zip(jax.tree_util.tree_leaves(old),
                                jax.tree_util.tree_leaves(new)):
                    wrapped = wrapped | jnp.any(n < o)
            steps_q = steps_q + (~halted).astype(jnp.int32)
            return (new_state, i + 1, halted2, overflow | ovf_q,
                    steps_q, nb2, nm2, ovf_by2, wrapped)

        qz = jnp.zeros((q,), bool)
        init = (state, jnp.asarray(0, jnp.int32), jnp.asarray(halted0, bool),
                qz, jnp.zeros((q,), jnp.int32), zeros, zeros, flags,
                jnp.zeros((), bool))
        return jax.lax.while_loop(cond, body, init)

    return loop


def _make_batched_chunk(mapped, registry, max_steps, check_overflow,
                        chunk_size, q):
    K = max(1, min(chunk_size, max_steps))
    zeros = registry.zeros()
    flags = registry.flags()
    bstep = _make_batched_step(mapped, q)

    def chunk(graph, state, i0, halted0, overflow0):
        def body(carry, _):
            state, i, halted, overflow, steps_q = carry
            stop = jnp.all(halted) | (i >= max_steps)
            if check_overflow:
                stop = stop | jnp.any(overflow)

            def do(operand):
                state, i, halted, overflow, steps_q = operand
                new_state, halted2, ovf_q, db, dm, dovf = bstep(
                    graph, state, i, halted)
                steps_q = steps_q + (~halted).astype(jnp.int32)
                return ((new_state, i + 1, halted2, overflow | ovf_q,
                         steps_q), (db, dm, dovf))

            def skip(operand):
                return (operand, (zeros, zeros, flags))

            return jax.lax.cond(stop, skip, do,
                                (state, i, halted, overflow, steps_q))

        (state, i, halted, overflow, steps_q), (db, dm, dovf) = jax.lax.scan(
            body, (state, i0, halted0, overflow0,
                   jnp.zeros((q,), jnp.int32)),
            None, length=K)
        return state, i, halted, overflow, steps_q, db, dm, dovf

    return chunk


def _make_serve_chunk(mapped, registry, max_steps, check_overflow,
                      chunk_size, q):
    """The serving substrate (``Engine.serve``): a scan of up to
    ``chunk_size`` supersteps whose carry is per-lane ``(age, halted,
    overflow)`` instead of a shared loop counter.

    Each lane is an independent tenancy: its step function sees its own
    ``age`` as the step index (so a query admitted at global superstep 40
    is bit-identical to a solo run starting at 0), its budget is ``age <
    max_steps``, and a lane that is halted, budget-exhausted, or
    unoccupied (the host marks it halted) is *dead* — state frozen bit
    for bit, traffic masked to zero, excluded from the union route pass
    via ``query_live``. The scan skips remaining iterations once every
    lane is dead, so a chunk never does work past its last live step."""
    K = max(1, chunk_size)
    zeros = registry.zeros()
    flags = registry.flags()

    def chunk(graph, state, age0, halted0, overflow0):
        def body(carry, _):
            state, age, halted, overflow = carry
            dead = halted | (age >= max_steps)
            stop = jnp.all(dead)
            if check_overflow:
                stop = stop | jnp.any(overflow)

            def do(operand):
                state, age, halted, overflow = operand
                live = ~(halted | (age >= max_steps))
                new_state, halt, ovf, db, dm, dovf = mapped(
                    graph, state, age, live)
                new_state = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(_qmask(live, n), n, o),
                    new_state, state)
                db = jax.tree_util.tree_map(
                    lambda d: jnp.where(live, d, 0), db)
                dm = jax.tree_util.tree_map(
                    lambda d: jnp.where(live, d, 0), dm)
                dovf = jax.tree_util.tree_map(
                    lambda d: jnp.where(live, d, False), dovf)
                # only a live lane's own vote may halt it: a dead lane's
                # (discarded) computation must not flip its flags
                halted2 = halted | (_qrow(halt, q) & live)
                overflow2 = overflow | (_qrow(ovf, q) & live)
                return ((new_state, age + live.astype(jnp.int32),
                         halted2, overflow2),
                        (db, dm, dovf, live.astype(jnp.int32)))

            def skip(operand):
                return (operand,
                        (zeros, zeros, flags, jnp.zeros((q,), jnp.int32)))

            return jax.lax.cond(stop, skip, do,
                                (state, age, halted, overflow))

        (state, age, halted, overflow), (db, dm, dovf, lives) = jax.lax.scan(
            body,
            (state, jnp.asarray(age0, jnp.int32),
             jnp.asarray(halted0, bool), jnp.asarray(overflow0, bool)),
            None, length=K)
        return state, age, halted, overflow, lives.sum(axis=0), db, dm, dovf

    return chunk


def _host_q_flag(v, q: int) -> np.ndarray:
    """Overflow flag leaf with trailing query axis -> (Q,) bool (ORs any
    leading worker/chunk axes)."""
    return np.asarray(v).astype(bool).reshape((-1, q)).any(axis=0)


def _batched_result(state, steps, halted_q, overflow_q, q_bytes, q_msgs,
                    steps_q, q_real, mode, dispatches, wall, step_times,
                    overhead, check_overflow, ovf_by=None,
                    wrapped=False) -> RunResult:
    # report only the real leading lanes — bucket-padding lanes (which
    # start halted) never surface in views, totals, or errors; their
    # aggregates ride along as the dead-pad audit trail (all zero)
    num_pad = len(steps_q) - q_real
    pad_steps = int(steps_q[q_real:].sum())
    pad_bytes = int(sum(v[q_real:].sum() for v in q_bytes.values()))
    pad_msgs = int(sum(v[q_real:].sum() for v in q_msgs.values()))
    halted_q = halted_q[:q_real]
    overflow_q = overflow_q[:q_real]
    steps_q = steps_q[:q_real]
    q_bytes = {k: v[:q_real] for k, v in q_bytes.items()}
    q_msgs = {k: v[:q_real] for k, v in q_msgs.items()}
    ovf_by = {k: v[:q_real] for k, v in (ovf_by or {}).items()}
    res = RunResult(
        state=state,
        steps=steps,
        halted=bool(halted_q.all()),
        bytes_by_channel={k: int(v.sum()) for k, v in q_bytes.items()},
        msgs_by_channel={k: int(v.sum()) for k, v in q_msgs.items()},
        wall_time_s=wall,
        step_times_s=step_times,
        mode=mode,
        dispatches=dispatches,
        host_overhead_s=overhead,
        num_queries=q_real,
        query_steps=steps_q,
        query_halted=halted_q,
        query_bytes_by_channel=q_bytes,
        query_msgs_by_channel=q_msgs,
        num_pad_lanes=num_pad,
        pad_steps=pad_steps,
        pad_bytes=pad_bytes,
        pad_msgs=pad_msgs,
        converged=bool(halted_q.all()),
        overflow_by_channel=ovf_by,
    )
    if check_overflow and overflow_q.any():
        qs = np.flatnonzero(overflow_q).tolist()
        bad = sorted(k for k, v in ovf_by.items() if np.asarray(v).any())
        raise errors.ChannelOverflowError(
            errors.overflow_message(steps - 1, bad, qids=qs),
            superstep=steps - 1, channels=bad, result=res, qids=qs)
    if wrapped:
        raise errors.TrafficWrapError(
            "per-channel traffic counters overflowed int32 inside the "
            "batched loop; bytes/msgs totals are unreliable — use "
            "mode='chunked' (exact host-side int64 accumulation) for "
            "runs this heavy",
            superstep=steps - 1, result=res)
    return res


def _exec_batched(compiled, graph, state0, mode, max_steps, check_overflow,
                  q, q_real) -> RunResult:
    # bucket-padding lanes start halted: dead end to end (no steps, no
    # wire slots, no traffic) instead of shadow-running query 0
    pad_halted = jnp.arange(q) >= q_real
    if mode == "fused":
        t0 = time.perf_counter()
        out = compiled(graph, state0, pad_halted)
        t_enq = time.perf_counter()
        state, steps, halted, overflow, steps_q, nb, nm, novf, wrapped = out
        jax.block_until_ready(state)
        t_dev = time.perf_counter()
        wall = t_dev - t0
        overhead = (t_enq - t0) + (time.perf_counter() - t_dev)
        return _batched_result(
            state, int(np.asarray(steps)), np.asarray(halted),
            np.asarray(overflow),
            {k: _host_q(v, q) for k, v in nb.items()},
            {k: _host_q(v, q) for k, v in nm.items()},
            np.asarray(steps_q).astype(np.int64), q_real, mode, 1, wall,
            [wall], overhead, check_overflow,
            ovf_by={k: _host_q_flag(v, q) for k, v in novf.items()},
            wrapped=bool(np.asarray(wrapped)))

    q_bytes: Dict[str, np.ndarray] = {}
    q_msgs: Dict[str, np.ndarray] = {}
    q_ovf: Dict[str, np.ndarray] = {}
    wrapped = False

    def acc(into, delta):
        nonlocal wrapped
        for k, v in delta.items():
            row = _host_q(v, q)
            if (row < 0).any():
                wrapped = True
            into[k] = into.get(k, 0) + row

    def acc_ovf(delta):
        for k, v in delta.items():
            row = _host_q_flag(v, q)
            q_ovf[k] = q_ovf.get(k, False) | row

    state = state0
    halted = pad_halted
    steps_q = np.zeros((q,), np.int64)
    overflow_acc = np.zeros((q,), bool)
    step_times = []
    dispatches = 0
    overhead = 0.0
    steps = 0
    t0 = time.perf_counter()

    if mode == "host":
        for step in range(max_steps):
            live = ~np.asarray(halted)
            if not live.any():
                break
            ts = time.perf_counter()
            state, halted, ovf_q, db, dm, dovf = compiled(
                graph, state, jnp.asarray(step, jnp.int32), halted)
            t_enq = time.perf_counter()
            jax.block_until_ready(state)
            t_dev = time.perf_counter()
            step_times.append(t_dev - ts)
            dispatches += 1
            steps = step + 1
            steps_q += live
            acc(q_bytes, db)
            acc(q_msgs, dm)
            acc_ovf(dovf)
            overflow_acc |= np.asarray(ovf_q)
            overhead += (t_enq - ts) + (time.perf_counter() - t_dev)
            if check_overflow and overflow_acc[:q_real].any():
                break
            if wrapped:
                break
    else:  # chunked
        i = jnp.asarray(0, jnp.int32)
        overflow = jnp.zeros((q,), bool)
        while True:
            ts = time.perf_counter()
            state, i, halted, overflow, d_steps, db, dm, dovf = compiled(
                graph, state, i, halted, overflow)
            t_enq = time.perf_counter()
            jax.block_until_ready(state)
            t_dev = time.perf_counter()
            step_times.append(t_dev - ts)
            dispatches += 1
            steps = int(np.asarray(i))
            steps_q += np.asarray(d_steps).astype(np.int64)
            acc(q_bytes, db)
            acc(q_msgs, dm)
            acc_ovf(dovf)
            overflow_acc |= np.asarray(overflow)
            overhead += (t_enq - ts) + (time.perf_counter() - t_dev)
            if check_overflow and overflow_acc[:q_real].any():
                break
            if wrapped:
                break
            if bool(np.asarray(halted).all()) or steps >= max_steps:
                break

    wall = time.perf_counter() - t0
    return _batched_result(
        state, steps, np.asarray(halted), overflow_acc, q_bytes, q_msgs,
        steps_q, q_real, mode, dispatches, wall, step_times, overhead,
        check_overflow, ovf_by=q_ovf, wrapped=wrapped)


def _exec_chunked(compiled, graph, state0, max_steps, check_overflow,
                  checkpoint_every: Optional[int] = None,
                  checkpoint_cb: Optional[Callable] = None,
                  resume: Optional[dict] = None) -> RunResult:
    f = jnp.zeros((), bool)
    bytes_acc: Dict[str, int] = {}
    msgs_acc: Dict[str, int] = {}
    ovf_acc: Dict[str, bool] = {}
    state = state0
    i = jnp.asarray(0, jnp.int32)
    halted, overflow = f, f
    resumed_from = 0
    if resume is not None:
        # restart from a dispatch-boundary snapshot: the scan continues
        # with the exact carry the uninterrupted run had at this boundary,
        # so states/steps/traffic replay bit for bit
        state = jax.tree_util.tree_map(jnp.asarray, resume["state"])
        i = jnp.asarray(int(resume["step"]), jnp.int32)
        bytes_acc = dict(resume["bytes_by_channel"])
        msgs_acc = dict(resume["msgs_by_channel"])
        ovf_acc = dict(resume.get("overflow_by_channel", {}))
        resumed_from = int(resume["step"])
    next_due = (resumed_from + checkpoint_every
                if checkpoint_every else None)
    chunk_times = []
    dispatches = 0
    overhead = 0.0
    wrapped_keys: set = set()
    t0 = time.perf_counter()
    while True:
        ts = time.perf_counter()
        state, i, halted, overflow, db, dm, dovf = compiled(
            graph, state, i, halted, overflow
        )
        t_enq = time.perf_counter()
        jax.block_until_ready(state)
        t_dev = time.perf_counter()
        chunk_times.append(t_dev - ts)
        dispatches += 1
        # stream the chunk's per-step stats out (skipped steps are zero);
        # a negative per-step delta is an in-step int32 wrap
        for k, v in db.items():
            if (np.asarray(v) < 0).any():
                wrapped_keys.add(k)
            bytes_acc[k] = bytes_acc.get(k, 0) + _host_int(v)
        for k, v in dm.items():
            if (np.asarray(v) < 0).any():
                wrapped_keys.add(k)
            msgs_acc[k] = msgs_acc.get(k, 0) + _host_int(v)
        for k, v in dovf.items():
            ovf_acc[k] = ovf_acc.get(k, False) or bool(np.asarray(v).any())
        steps = int(np.asarray(i))
        halt_now = bool(np.asarray(halted))
        overflowed = check_overflow and bool(np.asarray(overflow))
        overhead += (t_enq - ts) + (time.perf_counter() - t_dev)
        if overflowed or wrapped_keys:
            break
        if halt_now or steps >= max_steps:
            break
        if (checkpoint_cb is not None and next_due is not None
                and steps >= next_due):
            checkpoint_cb({
                "step": steps,
                "state": jax.tree_util.tree_map(np.asarray, state),
                "bytes_by_channel": dict(bytes_acc),
                "msgs_by_channel": dict(msgs_acc),
                "overflow_by_channel": dict(ovf_acc),
                "dispatches": dispatches,
            })
            next_due = steps + checkpoint_every
    wall = time.perf_counter() - t0
    halted_b = bool(np.asarray(halted))
    res = RunResult(
        state=state,
        steps=steps,
        halted=halted_b,
        bytes_by_channel=bytes_acc,
        msgs_by_channel=msgs_acc,
        wall_time_s=wall,
        step_times_s=chunk_times,
        mode="chunked",
        dispatches=dispatches,
        compile_time_s=0.0,
        host_overhead_s=overhead,
        converged=halted_b,
        overflow_by_channel=ovf_acc,
        resumed_from=resumed_from,
    )
    if overflowed:
        bad = sorted(k for k, v in ovf_acc.items() if v)
        raise errors.ChannelOverflowError(
            errors.overflow_message(steps - 1, bad),
            superstep=steps - 1, channels=bad, result=res)
    if wrapped_keys:
        bad = sorted(wrapped_keys)
        raise errors.TrafficWrapError(
            f"int32 traffic counter wrapped in channel(s) {', '.join(bad)} "
            f"by superstep {steps - 1} — per-step traffic exceeds int32 "
            "range", superstep=steps - 1, channels=bad, result=res)
    return res
