"""The worker runtime (paper Fig. 4), SPMD-style.

A superstep is a jitted function mapped over the worker axis; channels
inside it communicate with axis-name collectives. Two interchangeable
backends execute the same step code:

  - ``vmap``: W logical workers on one device (tests/benchmarks on CPU);
  - ``shard_map``: W shards on a real mesh (the deployment path).

Orthogonally, three *execution modes* drive the superstep loop:

  - ``fused`` (default): the whole loop runs on device inside a single
    ``jax.lax.while_loop`` dispatch — halt vote, overflow latch, step
    counter and per-channel traffic all live in the loop carry. One
    host→device round-trip per *run* instead of per *superstep*.
  - ``chunked``: ``jax.lax.scan`` over ``chunk_size`` supersteps per
    dispatch; control returns to the host at chunk boundaries for stat
    streaming (int64-safe host accumulation) and max-step enforcement.
  - ``host``: the legacy Python loop — one jitted dispatch plus a
    blocking device→host readback per superstep. Kept as the baseline
    the fusion benchmark measures against.

The fused/chunked carries need a fixed-shape stats pytree, so the runtime
performs a one-time dry trace (``jax.eval_shape`` — no compute) of the
mapped step to discover the ``ChannelRegistry``: the set of channel names
and their per-step stat shapes. Algorithms may also declare their
channels explicitly via ``channels=(...)``; the discovered set is then
validated against the declaration.

Voting-to-halt: the step function returns a local halt vote; the runtime
ANDs votes across workers (psum). In fused/chunked mode the AND result
feeds the loop condition on device; in host mode it is pulled back and
checked in Python.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregator
from repro.core.channel import ChannelContext, ChannelRegistry, key_under
from repro.graph.pgraph import PartitionedGraph

AXIS = "workers"


@dataclasses.dataclass
class RunResult:
    state: Any
    steps: int
    halted: bool
    bytes_by_channel: Dict[str, int]
    msgs_by_channel: Dict[str, int]
    wall_time_s: float
    step_times_s: list
    # Execution metadata (new fields default so callers constructing the
    # seed-era 7-tuple keep working).
    mode: str = "host"
    dispatches: int = 0
    compile_time_s: float = 0.0
    # Host time spent *driving* the run — dispatch enqueues, flag/stat
    # readbacks and Python bookkeeping — excluding device waits and (for
    # host mode) the step-0 trace+compile. This is the per-superstep cost
    # the fused modes amortize to once per dispatch.
    host_overhead_s: float = 0.0

    @property
    def total_bytes(self) -> int:
        return int(sum(self.bytes_by_channel.values()))

    @property
    def total_msgs(self) -> int:
        return int(sum(self.msgs_by_channel.values()))

    # -- namespaced (composed-channel) attribution helpers ----------------

    def bytes_under(self, prefix: str) -> int:
        """Total bytes accounted under a namespaced key prefix."""
        return int(sum(v for k, v in self.bytes_by_channel.items()
                       if key_under(k, prefix)))

    def msgs_under(self, prefix: str) -> int:
        """Total messages accounted under a namespaced key prefix."""
        return int(sum(v for k, v in self.msgs_by_channel.items()
                       if key_under(k, prefix)))


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (jax.shard_map vs experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental import shard_map as _sm

    return _sm.shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def _scalar(x):
    """() view of a flag that may be per-worker replicated ((W,) or ())."""
    return jnp.asarray(x).reshape(-1)[0] if jnp.ndim(x) else jnp.asarray(x)


def _host_int(v) -> int:
    """Device stat leaf -> exact host int (int64-safe accumulation)."""
    return int(np.asarray(v).astype(np.int64).sum())


def run_supersteps(
    graph: PartitionedGraph,
    step_fn: Callable,
    state0: Any,
    max_steps: int = 10_000,
    backend: str = "vmap",
    mesh: Optional[jax.sharding.Mesh] = None,
    axis: str = AXIS,
    check_overflow: bool = True,
    mode: Optional[str] = None,
    chunk_size: int = 64,
    channels: Optional[Any] = None,
) -> RunResult:
    """Run `step_fn(ctx, graph_shard, state_shard, step)` to halt.

    state0: pytree with per-vertex leaves of shape (W, n_loc, ...).
    step_fn returns (new_state, halt_local_bool) and may also return a
    third element `overflow` (bool) which the runtime surfaces as an error.

    mode: "fused" (default), "chunked", or "host" — see module docstring.
    channels: optional explicit channel declaration, validated against
      the dry-trace discovery (a mismatch is a programming error). Either
      a sequence of stat-key names, a composed channel (any object with
      ``channel_names()``, e.g. ``repro.core.compose.Stacked``), or a
      mixed sequence of both.
    """
    W, n_loc = graph.num_workers, graph.n_loc
    if mode is None:
        mode = "fused"
    if mode not in ("fused", "chunked", "host"):
        raise ValueError(f"unknown execution mode {mode!r}")

    def make_shard_step(registry: Optional[ChannelRegistry]):
        def shard_step(g_shard, state_shard, step_idx):
            ctx = ChannelContext(axis, W, n_loc, registry=registry)
            out = step_fn(ctx, g_shard, state_shard, step_idx)
            if len(out) == 3:
                new_state, halt, overflow = out
            else:
                new_state, halt = out
                overflow = jnp.asarray(False)
            halt_all = aggregator.all_halted(ctx, halt)
            overflow_any = jax.lax.psum(
                jnp.asarray(overflow, jnp.int32), axis) > 0
            nbytes, nmsgs = ctx.stats()
            return new_state, halt_all, overflow_any, nbytes, nmsgs

        return shard_step

    def map_shards(shard_step):
        if backend == "vmap":
            return jax.vmap(shard_step, in_axes=(0, 0, None), axis_name=axis)
        if backend == "shard_map":
            assert mesh is not None
            P = jax.sharding.PartitionSpec
            return _shard_map(
                shard_step,
                mesh=mesh,
                in_specs=(P(axis), P(axis), P()),
                out_specs=(P(axis), P(), P(), P(), P()),
            )
        raise ValueError(backend)

    # --- channel registry: one-time dry trace (no compute). Host mode
    # consumes open per-step dicts and needs no fixed carry, so it skips
    # the extra trace unless a declaration should be validated. ----------
    registry = None
    if mode in ("fused", "chunked") or channels is not None:
        probe = map_shards(make_shard_step(None))
        out_struct = jax.eval_shape(
            lambda s, i: probe(graph, s, i), state0, jnp.asarray(0, jnp.int32)
        )
        _, _, _, bytes_struct, _ = out_struct
        registry = ChannelRegistry.from_stats_structure(bytes_struct)
        if channels is not None:
            from repro.core import compose

            declared = tuple(sorted(compose.channel_names_of(channels)))
            if declared != registry.names:
                raise ValueError(
                    f"declared channels {declared} != traced channels "
                    f"{registry.names}"
                )

    mapped = map_shards(make_shard_step(registry))

    def one_step(state, step_idx):
        return mapped(graph, state, step_idx)

    if mode == "host":
        return _run_host(one_step, state0, max_steps, check_overflow)
    if mode == "fused":
        return _run_fused(one_step, registry, state0, max_steps,
                          check_overflow)
    return _run_chunked(one_step, registry, state0, max_steps,
                        check_overflow, chunk_size)


# ---------------------------------------------------------------------------
# host mode: one dispatch + blocking readback per superstep (baseline)
# ---------------------------------------------------------------------------


def _run_host(one_step, state0, max_steps, check_overflow) -> RunResult:
    stepper = jax.jit(one_step)
    bytes_acc: Dict[str, int] = {}
    msgs_acc: Dict[str, int] = {}
    state = state0
    halted = False
    t0 = time.perf_counter()
    step_times = []
    overhead = 0.0
    step = -1  # so max_steps=0 reports zero executed supersteps
    for step in range(max_steps):
        ts = time.perf_counter()
        state, halt_all, overflow, nbytes, nmsgs = stepper(
            state, jnp.asarray(step, jnp.int32)
        )
        t_enq = time.perf_counter()
        jax.block_until_ready(state)
        t_dev = time.perf_counter()
        step_times.append(t_dev - ts)
        if check_overflow and bool(np.asarray(overflow).reshape(-1)[0]):
            raise RuntimeError(
                f"channel capacity overflow at superstep {step} — "
                "increase the channel capacity in the routing plan"
            )
        for k, v in nbytes.items():
            bytes_acc[k] = bytes_acc.get(k, 0) + _host_int(v)
        for k, v in nmsgs.items():
            msgs_acc[k] = msgs_acc.get(k, 0) + _host_int(v)
        halt_now = bool(np.asarray(halt_all).reshape(-1)[0])
        # dispatch enqueue (step 0 is trace+compile — not counted) plus
        # readback/bookkeeping time: the host cost of driving one step
        if step > 0:
            overhead += t_enq - ts
        overhead += time.perf_counter() - t_dev
        if halt_now:
            halted = True
            break
    wall = time.perf_counter() - t0
    return RunResult(
        state=state,
        steps=step + 1,
        halted=halted,
        bytes_by_channel=bytes_acc,
        msgs_by_channel=msgs_acc,
        wall_time_s=wall,
        step_times_s=step_times,
        mode="host",
        dispatches=step + 1,
        host_overhead_s=overhead,
    )


# ---------------------------------------------------------------------------
# fused mode: the entire superstep loop is one lax.while_loop dispatch
# ---------------------------------------------------------------------------


def _run_fused(one_step, registry, state0, max_steps,
               check_overflow) -> RunResult:
    zeros = registry.zeros()

    def loop(state):
        def cond(carry):
            _, i, halted, overflow, _, _, _ = carry
            go = (~halted) & (i < max_steps)
            if check_overflow:
                go = go & (~overflow)
            return go

        def body(carry):
            state, i, _, overflow, nb, nm, wrapped = carry
            new_state, halt, ovf, db, dm = one_step(state, i)
            nb2 = jax.tree_util.tree_map(jnp.add, nb, db)
            nm2 = jax.tree_util.tree_map(jnp.add, nm, dm)
            # per-step deltas are non-negative, so a decreasing accumulator
            # means the int32 counter wrapped — latch it for the host
            for old, new in ((nb, nb2), (nm, nm2)):
                for o, n in zip(jax.tree_util.tree_leaves(old),
                                jax.tree_util.tree_leaves(new)):
                    wrapped = wrapped | jnp.any(n < o)
            return (new_state, i + 1, _scalar(halt),
                    overflow | _scalar(ovf), nb2, nm2, wrapped)

        init = (state, jnp.asarray(0, jnp.int32), jnp.zeros((), bool),
                jnp.zeros((), bool), zeros, zeros, jnp.zeros((), bool))
        return jax.lax.while_loop(cond, body, init)

    tc = time.perf_counter()
    compiled = jax.jit(loop).lower(state0).compile()
    compile_s = time.perf_counter() - tc

    t0 = time.perf_counter()
    state, steps, halted, overflow, nb, nm, wrapped = compiled(state0)
    t_enq = time.perf_counter()
    jax.block_until_ready(state)
    t_dev = time.perf_counter()
    wall = t_dev - t0
    if bool(np.asarray(wrapped)):
        import warnings

        warnings.warn(
            "per-channel traffic counters overflowed int32 inside the fused "
            "loop; bytes/msgs totals are unreliable — use mode='chunked' "
            "(exact host-side int64 accumulation) for runs this heavy",
            RuntimeWarning,
        )

    steps = int(np.asarray(steps))
    halted_b = bool(np.asarray(halted))
    bytes_by = {k: _host_int(v) for k, v in nb.items()}
    msgs_by = {k: _host_int(v) for k, v in nm.items()}
    overhead = (t_enq - t0) + (time.perf_counter() - t_dev)
    if check_overflow and bool(np.asarray(overflow)):
        raise RuntimeError(
            f"channel capacity overflow at superstep {steps - 1} — "
            "increase the channel capacity in the routing plan"
        )
    return RunResult(
        state=state,
        steps=steps,
        halted=halted_b,
        bytes_by_channel=bytes_by,
        msgs_by_channel=msgs_by,
        wall_time_s=wall,
        step_times_s=[wall],
        mode="fused",
        dispatches=1,
        compile_time_s=compile_s,
        host_overhead_s=overhead,
    )


# ---------------------------------------------------------------------------
# chunked mode: lax.scan over K supersteps per dispatch; the host streams
# per-step stats (exact int64 accumulation) at every chunk boundary
# ---------------------------------------------------------------------------


def _run_chunked(one_step, registry, state0, max_steps, check_overflow,
                 chunk_size) -> RunResult:
    K = max(1, min(chunk_size, max_steps))
    zeros = registry.zeros()

    def chunk(state, i0, halted0, overflow0):
        def body(carry, _):
            state, i, halted, overflow = carry
            stop = halted | (i >= max_steps)
            if check_overflow:
                stop = stop | overflow

            def do(operand):
                state, i = operand
                new_state, halt, ovf, db, dm = one_step(state, i)
                return ((new_state, i + 1, _scalar(halt),
                         overflow | _scalar(ovf)), (db, dm))

            def skip(operand):
                state, i = operand
                # skipped steps contribute zero traffic
                return ((state, i, halted, overflow), (zeros, zeros))

            return jax.lax.cond(stop, skip, do, (state, i))

        (state, i, halted, overflow), (db, dm) = jax.lax.scan(
            body, (state, i0, halted0, overflow0), None, length=K
        )
        return state, i, halted, overflow, db, dm

    f = jnp.zeros((), bool)
    tc = time.perf_counter()
    compiled = (
        jax.jit(chunk)
        .lower(state0, jnp.asarray(0, jnp.int32), f, f)
        .compile()
    )
    compile_s = time.perf_counter() - tc

    bytes_acc: Dict[str, int] = {}
    msgs_acc: Dict[str, int] = {}
    state = state0
    i = jnp.asarray(0, jnp.int32)
    halted, overflow = f, f
    chunk_times = []
    dispatches = 0
    overhead = 0.0
    t0 = time.perf_counter()
    while True:
        ts = time.perf_counter()
        state, i, halted, overflow, db, dm = compiled(
            state, i, halted, overflow
        )
        t_enq = time.perf_counter()
        jax.block_until_ready(state)
        t_dev = time.perf_counter()
        chunk_times.append(t_dev - ts)
        dispatches += 1
        # stream the chunk's per-step stats out (skipped steps are zero)
        for k, v in db.items():
            bytes_acc[k] = bytes_acc.get(k, 0) + _host_int(v)
        for k, v in dm.items():
            msgs_acc[k] = msgs_acc.get(k, 0) + _host_int(v)
        steps = int(np.asarray(i))
        halt_now = bool(np.asarray(halted))
        overhead += (t_enq - ts) + (time.perf_counter() - t_dev)
        if check_overflow and bool(np.asarray(overflow)):
            raise RuntimeError(
                f"channel capacity overflow at superstep {steps - 1} — "
                "increase the channel capacity in the routing plan"
            )
        if halt_now or steps >= max_steps:
            break
    wall = time.perf_counter() - t0
    return RunResult(
        state=state,
        steps=steps,
        halted=bool(np.asarray(halted)),
        bytes_by_channel=bytes_acc,
        msgs_by_channel=msgs_acc,
        wall_time_s=wall,
        step_times_s=chunk_times,
        mode="chunked",
        dispatches=dispatches,
        compile_time_s=compile_s,
        host_overhead_s=overhead,
    )
