"""Engine — a compile-once execution session for VertexPrograms.

Every legacy ``run(...)`` call re-traced and re-compiled its superstep
loop, even when the same algorithm ran again on the same (or a
same-shape) graph — per-call compile latency that dominates small runs
and multiplies across benchmarks sweeps. An :class:`Engine` is the
session object that amortizes it: it compiles a
(:class:`~repro.pregel.program.VertexProgram`, graph-shape, mode) key at
most once and replays the cached executable for every subsequent run.

    eng = Engine(mode="fused")
    res1 = eng.run(prog, pg_a)      # compiles
    res2 = eng.run(prog, pg_a)      # cache hit — no trace, no compile
    res3 = eng.run(prog, pg_b)      # cache hit too, if pg_b has pg_a's
                                    # shape signature (identical caps)

Cache telemetry lives on the engine (``compiles`` / ``cache_hits``) and
is stamped onto every ``RunResult`` (``cache_hit``, ``engine_compiles``,
``engine_cache_hits``) so benchmarks can report exactly what a session
paid. Correctness does not depend on the cache: a shape signature covers
*every* static that enters the compiled loop (see
:func:`repro.pregel.runtime.graph_signature`), so a hit is bit-identical
to a fresh compile.

:meth:`Engine.run_batch` is the batched query plane: one compiled loop
advances Q query instances (e.g. Q SSSP sources) of a query-parametric
program (``VertexProgram.query_init``) per superstep, with per-query
halt voting and per-query step/traffic attribution. The compile-cache
key uses the *power-of-two-bucketed* batch cap, not Q itself — the
batch is padded to the bucket by repeating the first query, so Q=20 and
Q=27 share one executable (the same trick the graph plans play with
their slot caps).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import compose, routing
from repro.graph.pgraph import PartitionedGraph
from repro.kernels import ops as kops
from repro.plan import features, planner as planning
from repro.pregel import checkpoint as ckpt_io
from repro.pregel import errors
from repro.pregel import runtime
from repro.pregel import serve as serving
from repro.pregel.program import VertexProgram


def bucket_queries(q: int) -> int:
    """Pow2 batch cap: the compiled query-axis width for a Q-query batch."""
    if q < 1:
        raise ValueError(f"need at least one query, got {q}")
    return 1 << (q - 1).bit_length()


class Engine:
    """Compile-once session for running VertexPrograms.

    backend/mesh/mode/chunk_size are fixed per engine (they select the
    compiled artifact); hold one engine per execution configuration and
    as many programs/graphs as you like flow through it.
    """

    def __init__(self, backend: str = "vmap",
                 mesh: Optional[jax.sharding.Mesh] = None,
                 mode: Optional[str] = None,
                 chunk_size: Optional[int] = None,
                 use_kernel: Optional[bool] = None,
                 route_impl: Optional[str] = None,
                 route_batch: Optional[str] = None,
                 dense_threshold: Optional[float] = None,
                 plan: Any = "manual",
                 on_overflow: str = "raise",
                 on_nonconverged: Optional[str] = None,
                 cap_scales: Optional[Dict[str, float]] = None,
                 max_retries: int = 8):
        if mode is not None and mode not in ("fused", "chunked", "host"):
            raise ValueError(f"unknown execution mode {mode!r}")
        if not (plan in ("manual", "auto")
                or isinstance(plan, planning.Plan)):
            raise ValueError(
                f"unknown plan {plan!r} (one of ('manual', 'auto') or a "
                "repro.plan.Plan)")
        if on_overflow not in ("raise", "escalate"):
            raise ValueError(
                f"unknown on_overflow {on_overflow!r} "
                "(one of ('raise', 'escalate'))")
        if on_nonconverged not in (None, "warn", "raise"):
            raise ValueError(
                f"unknown on_nonconverged {on_nonconverged!r} "
                "(one of (None, 'warn', 'raise'))")
        self.backend = backend
        self.mesh = mesh
        # which knobs the caller set explicitly — they win over any plan
        # (the planner records them with source "explicit")
        self._explicit = {
            "mode": mode, "chunk_size": chunk_size,
            "use_kernel": use_kernel, "route_impl": route_impl,
            "route_batch": route_batch, "dense_threshold": dense_threshold,
        }
        self.mode = "fused" if mode is None else mode
        self.chunk_size = 64 if chunk_size is None else chunk_size
        # data-plane knobs, resolved once per engine (None = env/backend
        # default — see repro.configs.knobs) and part of every cache key:
        # a kernel-path loop and a reference-path loop are different
        # executables.
        self.use_kernel = kops.resolve_use_kernel(use_kernel)
        self.route_impl = routing.resolve_impl(route_impl)
        # how routed channels batch the query axis in run_batch compiles
        # ("union" = shared union-frontier route pass, "lane" = per-lane)
        self.route_batch = routing.resolve_batch(route_batch)
        self.dense_threshold = compose.resolve_dense_threshold(
            dense_threshold)
        # plan policy: "manual" = the resolved knobs above, verbatim;
        # "auto" = the cost-model planner decides per (program, graph
        # shape, Q); a Plan instance = use it (explicit knobs still win)
        self.plan_policy = plan
        self._planner = (planning.Planner()
                         if plan == "auto" else None)
        self._manual_plan: Optional[planning.Plan] = None
        self._cache: Dict[Tuple, runtime.CompiledSupersteps] = {}
        self.compiles = 0
        self.cache_hits = 0
        self.runs = 0
        # -- resilience policy (repro.pregel.errors) ----------------------
        # on_overflow="escalate": on ChannelOverflowError, double the
        # offending channels' capacity scales (pow2 re-bucketed at trace
        # time) and replay, up to max_retries attempts; every escalation
        # is recorded on RunResult.recovery, and the final scales are
        # memoized per planner fingerprint so repeat runs of the same
        # problem start right-sized.
        self.on_overflow = on_overflow
        self.on_nonconverged = on_nonconverged
        self.max_retries = int(max_retries)
        self._base_scales = self._norm_scales(cap_scales or {})
        # learned capacity scales: fingerprint.cache_key() -> scales dict
        self._learned: Dict[str, Dict[str, float]] = {}

    # -- introspection ----------------------------------------------------

    @property
    def cache_size(self) -> int:
        return len(self._cache)

    def stats(self) -> Dict[str, int]:
        return {"compiles": self.compiles, "cache_hits": self.cache_hits,
                "cached_executables": self.cache_size, "runs": self.runs}

    # -- planning ---------------------------------------------------------

    def resolve_plan(self, prog: VertexProgram, pg: PartitionedGraph,
                     num_queries: int = 0) -> planning.Plan:
        """The Plan a compile of ``prog`` on ``pg`` (Q query lanes) runs
        under, per the engine's plan policy. Explicit constructor knobs
        win under every policy; ``"auto"`` consults the cost-model
        planner (calibration probes cached on disk — never in this
        engine's compile cache, never in ``stats()``)."""
        if self.plan_policy == "auto":
            overrides = {k: getattr(self, k)
                         for k, raw in self._explicit.items()
                         if raw is not None}
            return self._planner.plan(prog, pg, num_queries=num_queries,
                                      overrides=overrides)
        if isinstance(self.plan_policy, planning.Plan):
            return self._given_plan()
        if self._manual_plan is None:
            self._manual_plan = planning.manual_plan(
                mode=self.mode, chunk_size=self.chunk_size,
                use_kernel=self.use_kernel, route_impl=self.route_impl,
                route_batch=self.route_batch,
                dense_threshold=self.dense_threshold,
                explicit=self._explicit)
        return self._manual_plan

    def _given_plan(self) -> planning.Plan:
        """A caller-supplied Plan instance, with any explicit constructor
        knobs replacing the plan's choices (explicit still wins)."""
        base = self.plan_policy
        over = {k: getattr(self, k) for k, raw in self._explicit.items()
                if raw is not None}
        if not over:
            return base
        decisions = tuple(
            planning.Decision(
                knob=d.knob, chosen=over[d.knob], source="explicit",
                candidates=d.candidates,
                reason="engine-constructor knob overrides the given plan")
            if d.knob in over else d
            for d in base.decisions)
        return dataclasses.replace(base, decisions=decisions, **over)

    # -- resilience: capacity-scale escalation ----------------------------

    @staticmethod
    def _norm_scales(scales: Dict[str, float]) -> Dict[str, float]:
        """Canonical form of a cap_scales dict: per-channel entries equal
        to the wildcard default are redundant and dropped, so an
        escalation that lands back on the default capacities keys the
        SAME cache entry as a plain run (warm executable, no recompile).
        """
        base = float(scales.get("*", 1.0))
        out: Dict[str, float] = {}
        if base != 1.0:
            out["*"] = base
        for k, v in scales.items():
            if k != "*" and float(v) != base:
                out[k] = float(v)
        return out

    def _fingerprint_key(self, prog: VertexProgram, pg: PartitionedGraph,
                         num_queries: int) -> Optional[str]:
        try:
            return features.fingerprint(
                prog, pg, num_queries=num_queries).cache_key()
        except Exception:
            return None

    def _effective_scales(self, prog: VertexProgram, pg: PartitionedGraph,
                          num_queries: int) -> Dict[str, float]:
        """Constructor cap_scales merged with any scales a previous
        escalation learned for this (program, graph shape, Q) problem —
        a repeat run starts right-sized instead of re-discovering the
        overflow one retry at a time."""
        scales = dict(self._base_scales)
        if self.on_overflow == "escalate":
            fp = self._fingerprint_key(prog, pg, num_queries)
            for k, v in self._learned.get(fp, {}).items():
                if v > scales.get(k, scales.get("*", 1.0)):
                    scales[k] = v
        return self._norm_scales(scales)

    def _remember_scales(self, prog: VertexProgram, pg: PartitionedGraph,
                         num_queries: int,
                         scales: Dict[str, float]) -> None:
        fp = self._fingerprint_key(prog, pg, num_queries)
        if fp is not None:
            self._learned[fp] = dict(scales)

    def _escalated(self, scales: Dict[str, float],
                   channels: Sequence[str]) -> Dict[str, float]:
        """Double the capacity scale of every overflowed channel (the
        trace re-buckets the scaled capacity to the next power of two).
        A global latch with no channel attribution escalates the
        wildcard — every channel grows."""
        out = dict(scales)
        for name in (list(channels) or ["*"]):
            out[name] = out.get(name, out.get("*", 1.0)) * 2.0
        return self._norm_scales(out)

    def _check_converged(self, prog: VertexProgram,
                         res: runtime.RunResult) -> None:
        if self.on_nonconverged is None or res.converged:
            return
        msg = (f"program {prog.name!r} did not converge: the max_steps "
               f"budget ({res.steps} supersteps) ran out before every "
               "vertex voted to halt")
        if self.on_nonconverged == "raise":
            raise errors.NonConvergenceError(
                msg, superstep=res.steps, result=res)
        warnings.warn(msg, RuntimeWarning, stacklevel=3)

    # -- execution --------------------------------------------------------

    def _compile_cached(self, prog: VertexProgram, pg: PartitionedGraph,
                        state0, ms: int, co: bool, key_extra: Tuple = (),
                        num_queries: Optional[int] = None,
                        serve_chunk: Optional[int] = None,
                        cap_scales: Optional[Dict[str, float]] = None):
        """The one cache-lookup path (run, run_batch, and serve share it,
        so a new config knob lands in every key or none): return
        ``(exe, hit, plan)`` and bump the session counters. The resolved
        Plan's knob tuple IS the configuration part of the cache key — a
        planner choice and the identical hand-set choice share one
        executable.

        ``serve_chunk`` selects the serving substrate: a chunked scan at
        that chunk size with per-lane ages, regardless of the plan's
        mode (the serve loop drives dispatches itself).
        """
        plan = self.resolve_plan(prog, pg,
                                 num_queries=(num_queries or 0))
        scales = cap_scales or {}
        key = (prog, ms, co, plan.key(),
               runtime.graph_signature(pg),
               runtime.state_signature(state0),
               tuple(sorted(scales.items()))) + key_extra
        exe = self._cache.get(key)
        hit = exe is not None
        if not hit:
            # compile_supersteps/execute scrub the graph themselves, so
            # any graph with this signature replays the executable
            mode = plan.mode if serve_chunk is None else "chunked"
            chunk = plan.chunk_size if serve_chunk is None else serve_chunk
            exe = runtime.compile_supersteps(
                pg, prog.step, state0, max_steps=ms, backend=self.backend,
                mesh=self.mesh, check_overflow=co, mode=mode,
                chunk_size=chunk, channels=prog.channels,
                use_kernel=plan.use_kernel, route_impl=plan.route_impl,
                route_batch=plan.route_batch,
                dense_threshold=plan.dense_threshold,
                num_queries=num_queries,
                serve=serve_chunk is not None,
                cap_scales=scales,
            )
            self._cache[key] = exe
            self.compiles += 1
        else:
            self.cache_hits += 1
        self.runs += 1
        return exe, hit, plan

    def _stamp(self, res: runtime.RunResult, prog: VertexProgram,
               exe: runtime.CompiledSupersteps, hit: bool,
               plan: Optional[planning.Plan] = None) -> runtime.RunResult:
        if not hit:
            res.compile_time_s = exe.compile_time_s
        res.program = prog.name
        res.cache_hit = hit
        res.plan = plan
        res.engine_compiles = self.compiles
        res.engine_cache_hits = self.cache_hits
        return res

    def run(self, prog: VertexProgram, pg: PartitionedGraph, *,
            max_steps: Optional[int] = None,
            check_overflow: Optional[bool] = None,
            checkpoint_every: Optional[int] = None,
            checkpoint_dir: Optional[str] = None,
            resume: Any = None) -> runtime.RunResult:
        """Run ``prog`` on ``pg``; compile only on a cache miss.

        Returns the runtime's ``RunResult`` with ``output`` set to
        ``prog.extract(pg, state)`` and the engine/cache metadata filled
        in. ``compile_time_s`` is 0 on cache hits — the compile was paid
        by an earlier run.

        ``checkpoint_every=K`` snapshots the chunked carry into
        ``checkpoint_dir`` at the first dispatch boundary at or past
        every K supersteps (chunked mode only — see
        ``repro.pregel.checkpoint``). ``resume`` takes a checkpoint path
        or :class:`~repro.pregel.checkpoint.Checkpoint` and continues
        from that boundary, bit-identical to the uninterrupted run.

        Under ``Engine(on_overflow="escalate")`` a channel-capacity
        overflow does not kill the run: the offending channels' caps are
        re-bucketed to the next power of two and the run replays, up to
        ``max_retries`` attempts. Escalations are reported on
        ``RunResult.recovery`` and remembered per (program, graph shape)
        so the next run starts right-sized.
        """
        ms = prog.max_steps if max_steps is None else max_steps
        co = prog.check_overflow if check_overflow is None else check_overflow
        state0 = prog.init(pg)

        resume_carry = None
        if resume is not None:
            ckpt = (resume if isinstance(resume, ckpt_io.Checkpoint)
                    else ckpt_io.load(resume))
            ckpt.validate(prog.name, pg, ms)
            resume_carry = ckpt.carry()
        checkpoint_cb = None
        if checkpoint_every is not None:
            if checkpoint_dir is None:
                raise ValueError(
                    "checkpoint_every needs checkpoint_dir to write into")

            def checkpoint_cb(snap):
                ckpt_io.save(
                    ckpt_io.Checkpoint(
                        program=prog.name, graph=ckpt_io.graph_hash(pg),
                        max_steps=ms, **snap),
                    checkpoint_dir)

        scales = self._effective_scales(prog, pg, 0)
        recovery: List[Dict[str, Any]] = []
        attempt = 0
        while True:
            exe, hit, plan = self._compile_cached(
                prog, pg, state0, ms, co, cap_scales=scales)
            try:
                raw = exe.execute(pg, state0,
                                  checkpoint_every=checkpoint_every,
                                  checkpoint_cb=checkpoint_cb,
                                  resume=resume_carry)
                break
            except errors.ChannelOverflowError as err:
                if self.on_overflow != "escalate" \
                        or attempt >= self.max_retries:
                    if recovery and err.result is not None:
                        err.result.recovery = recovery
                    raise
                scales = self._escalated(scales, err.channels)
                recovery.append({
                    "attempt": attempt, "superstep": err.superstep,
                    "channels": tuple(err.channels),
                    "cap_scales": dict(scales)})
                attempt += 1
        res = self._stamp(raw, prog, exe, hit, plan)
        if recovery:
            res.recovery = recovery
            self._remember_scales(prog, pg, 0, scales)
        res.output = prog.extract(pg, res.state)
        self._check_converged(prog, res)
        return res

    def run_many(self, prog: VertexProgram,
                 graphs: Iterable[PartitionedGraph],
                 **kw) -> "ManyResults":
        """Run one program over many graphs; same-shape graphs after the
        first ride the cached executable. The returned list exposes the
        per-item compile-cache outcome (``.cache_hits`` / ``.hit_count``)
        so a sweep can report exactly which items replayed for free."""
        return ManyResults(self.run(prog, pg, **kw) for pg in graphs)

    def run_batch(self, prog: VertexProgram, pg: PartitionedGraph,
                  queries: Sequence[Any], *,
                  max_steps: Optional[int] = None,
                  check_overflow: Optional[bool] = None
                  ) -> runtime.RunResult:
        """Run Q query instances of ``prog`` on ``pg`` in ONE compiled
        loop (query axis vmapped inside the worker mapping, per-query
        halt voting — see ``repro.pregel.runtime``).

        ``queries`` are the per-query problem inputs fed to
        ``prog.query_init(pg, query)`` (e.g. SSSP source vertices). The
        batch is padded to the pow2 bucket cap by repeating the first
        query, so nearby batch sizes share one executable; padded lanes
        are sliced away before anything is reported.

        Returns the RunResult with per-query views: ``outputs`` (list of
        Q extracted answers — also on ``output``), ``query_steps``,
        ``query_halted``, and ``query_bytes``/``query_msgs``; the
        dict-of-int totals (``bytes_by_channel``…) cover the Q real
        queries only.
        """
        if prog.query_init is None:
            raise ValueError(
                f"program {prog.name!r} declares no query axis "
                "(VertexProgram.query_init) — it cannot be batched")
        queries = list(queries)
        q = len(queries)
        cap = bucket_queries(q)
        per_query = [prog.query_init(pg, query) for query in queries]
        # pad lanes reuse the first real state by reference — jnp.stack
        # copies anyway, so re-running query_init for them buys nothing
        per_query += [per_query[0]] * (cap - q)
        state0 = jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves, axis=1), *per_query)

        ms = prog.max_steps if max_steps is None else max_steps
        co = prog.check_overflow if check_overflow is None else check_overflow
        scales = self._effective_scales(prog, pg, cap)
        recovery: List[Dict[str, Any]] = []
        attempt = 0
        while True:
            exe, hit, plan = self._compile_cached(
                prog, pg, state0, ms, co, key_extra=("batch", cap),
                num_queries=cap, cap_scales=scales)
            try:
                raw = exe.execute(pg, state0, num_real_queries=q)
                break
            except errors.ChannelOverflowError as err:
                if self.on_overflow != "escalate" \
                        or attempt >= self.max_retries:
                    if recovery and err.result is not None:
                        err.result.recovery = recovery
                    raise
                scales = self._escalated(scales, err.channels)
                recovery.append({
                    "attempt": attempt, "superstep": err.superstep,
                    "channels": tuple(err.channels),
                    "qids": tuple(err.qids),
                    "cap_scales": dict(scales)})
                attempt += 1
        # the executor slices every per-query view/total/error to the Q
        # real lanes; only the raw carried state keeps the padded width
        res = self._stamp(raw, prog, exe, hit, plan)
        if recovery:
            res.recovery = recovery
            self._remember_scales(prog, pg, cap, scales)
        res.outputs = [
            prog.extract(pg, jax.tree_util.tree_map(
                lambda leaf, _qi=qi: leaf[:, _qi], res.state))
            for qi in range(q)
        ]
        res.output = res.outputs
        self._check_converged(prog, res)
        return res

    def serve(self, prog: VertexProgram, pg: PartitionedGraph,
              requests, *, num_lanes: int = 8,
              chunk_size: Optional[int] = None,
              max_steps: Optional[int] = None,
              check_overflow: Optional[bool] = None,
              faults: Optional[Sequence] = None,
              on_fault: str = "quarantine"
              ) -> serving.ServeResult:
        """Continuous-batching query service: serve a stream of queries
        through ``num_lanes`` always-on lanes, admitting from the queue
        at every chunk (dispatch) boundary as halted queries vacate
        their lanes (see ``repro.pregel.serve``).

        ``requests`` is a :class:`~repro.pregel.serve.QueryQueue`
        (arrival times in supersteps) or a plain iterable of query
        values (all arrive at t=0). Admission granularity is
        ``chunk_size`` supersteps (default: the engine's chunk size).
        One executable is compiled for the whole session — refills
        rewrite lane state in place and never re-trace — and it is
        cached under (program, graph shape, lanes, chunk), so a second
        session with the same shape replays it warm.

        Every served query is bit-identical to a solo ``Engine.run``:
        per-lane ages stand in for the step counter, so a query admitted
        at clock 400 sees step indices 0,1,2,… exactly as a fresh run
        would, and its harvested output/steps/traffic match the solo
        run's. Returns a :class:`~repro.pregel.serve.ServeResult` with
        per-query :class:`~repro.pregel.serve.QueryRecord` entries
        (qid order) and session aggregates.

        A lane that hits a channel-capacity overflow is **quarantined**
        by default (``on_fault="quarantine"``): its query is harvested
        with ``status="overflow"`` and no output, the lane is recycled,
        and every other query completes bit-identical to its solo run.
        ``on_fault="raise"`` keeps the legacy behaviour and raises
        :class:`~repro.pregel.errors.ChannelOverflowError` with the
        failed qids. ``faults`` takes deterministic
        :class:`~repro.pregel.serve.FaultSpec` injections (force an
        overflow or a step-budget exhaustion on a chosen qid at a chosen
        per-query step) for resilience drills — injected failures are
        flagged ``injected=True`` on their records.
        """
        if on_fault not in ("quarantine", "raise"):
            raise ValueError(
                f"unknown on_fault {on_fault!r} "
                "(one of ('quarantine', 'raise'))")
        if prog.query_init is None:
            raise ValueError(
                f"program {prog.name!r} declares no query axis "
                "(VertexProgram.query_init) — it cannot be served")
        if num_lanes < 1:
            raise ValueError(f"need at least one lane, got {num_lanes}")
        queue = serving.as_queue(requests)
        ms = prog.max_steps if max_steps is None else max_steps
        co = prog.check_overflow if check_overflow is None else check_overflow
        chunk = self.chunk_size if chunk_size is None else chunk_size
        if len(queue) == 0:
            return serving.ServeResult(
                program=prog.name, records=[], num_lanes=num_lanes,
                chunk_size=chunk, max_steps=ms, supersteps=0, clock=0,
                dispatches=0, wall_time_s=0.0, bytes_by_channel={},
                msgs_by_channel={}, route_batch=self.route_batch,
                cache_hit=True, engine_compiles=self.compiles,
                engine_cache_hits=self.cache_hits)
        # lane-state template: shapes/dtypes come from any query's init
        # state (all lanes are overwritten on admission; unoccupied
        # lanes are dead — halted, zero traffic, out of the union)
        template = prog.query_init(pg, queue.peek_query())
        state0 = jax.tree_util.tree_map(
            lambda leaf: jnp.repeat(leaf[:, None], num_lanes, axis=1),
            template)
        exe, hit, plan = self._compile_cached(
            prog, pg, state0, ms, co,
            key_extra=("serve", num_lanes, chunk),
            num_queries=num_lanes, serve_chunk=chunk)
        res = serving.serve_loop(exe, prog, pg, state0, queue, num_lanes,
                                 chunk, ms, co, faults=faults,
                                 on_fault=on_fault)
        res.program = prog.name
        res.route_batch = exe.route_batch
        res.plan = plan
        res.cache_hit = hit
        if not hit:
            res.compile_time_s = exe.compile_time_s
        res.engine_compiles = self.compiles
        res.engine_cache_hits = self.cache_hits
        return res


class ManyResults(List[runtime.RunResult]):
    """``Engine.run_many``'s return value: a plain result list that also
    exposes the per-item compile-cache outcome."""

    @property
    def cache_hits(self) -> List[bool]:
        return [r.cache_hit for r in self]

    @property
    def hit_count(self) -> int:
        return sum(r.cache_hit for r in self)


def run_program(prog: VertexProgram, pg: PartitionedGraph, *,
                backend: str = "vmap", mesh=None, mode: Optional[str] = None,
                chunk_size: int = 64, max_steps: Optional[int] = None,
                check_overflow: Optional[bool] = None,
                use_kernel: Optional[bool] = None,
                route_impl: Optional[str] = None,
                route_batch: Optional[str] = None) -> runtime.RunResult:
    """One-shot convenience: a throwaway single-run Engine. The legacy
    per-algorithm ``run()`` wrappers delegate here."""
    eng = Engine(backend=backend, mesh=mesh, mode=mode,
                 chunk_size=chunk_size, use_kernel=use_kernel,
                 route_impl=route_impl, route_batch=route_batch)
    return eng.run(prog, pg, max_steps=max_steps,
                   check_overflow=check_overflow)
