"""Host-side checkpointing for chunked-mode runs.

``Engine.run(prog, pg, checkpoint_every=K, checkpoint_dir=...)`` snapshots
the chunked loop's carry at the first dispatch boundary at or past every
K supersteps: the step counter, the full state pytree (host numpy), and
the traffic accumulated so far. A run killed mid-fixpoint restarts from
the latest snapshot (``Engine.run(..., resume=ckpt)`` /
``repro run <prog> --resume <path>``) and is **bit-identical** to the
uninterrupted run — the scan continues with exactly the carry the
original run had at that boundary, so states, step counts and channel
traffic all replay byte for byte (pinned by tests/test_resilience.py).

Snapshots are self-describing: program name, graph signature hash and
max_steps ride along, and :meth:`Checkpoint.validate` rejects a resume
against the wrong program or a different-shaped graph with an actionable
message instead of silently diverging. Files are written atomically
(tmp + rename) so a kill during checkpointing never leaves a torn file.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import pickle
import tempfile
from typing import Any, Dict, Optional


def graph_hash(pg) -> str:
    """Stable short hash of a graph's compile signature — what a resumed
    run must share with the run that wrote the checkpoint."""
    from repro.pregel.runtime import graph_signature

    return hashlib.sha1(repr(graph_signature(pg)).encode()).hexdigest()[:16]


@dataclasses.dataclass
class Checkpoint:
    """One dispatch-boundary snapshot of a chunked run."""

    program: str
    graph: str                    # graph_hash(pg) at save time
    max_steps: int
    step: int                     # supersteps completed at this boundary
    state: Any                    # state pytree, leaves as host numpy
    bytes_by_channel: Dict[str, int]
    msgs_by_channel: Dict[str, int]
    overflow_by_channel: Dict[str, bool]
    dispatches: int

    def carry(self) -> dict:
        """The resume carry ``repro.pregel.runtime._exec_chunked`` takes."""
        return {
            "step": self.step,
            "state": self.state,
            "bytes_by_channel": dict(self.bytes_by_channel),
            "msgs_by_channel": dict(self.msgs_by_channel),
            "overflow_by_channel": dict(self.overflow_by_channel),
        }

    def validate(self, program: str, pg, max_steps: int) -> None:
        if program != self.program:
            raise ValueError(
                f"checkpoint was written by program {self.program!r}, "
                f"cannot resume {program!r} from it")
        gh = graph_hash(pg)
        if gh != self.graph:
            raise ValueError(
                f"checkpoint graph signature {self.graph} does not match "
                f"this graph ({gh}) — resume needs the same partitioned "
                "graph shape (same scale/workers/partitioner/caps)")
        if max_steps != self.max_steps:
            raise ValueError(
                f"checkpoint was taken under max_steps={self.max_steps}, "
                f"resuming with max_steps={max_steps} would not replay the "
                "uninterrupted run — pass the same step budget")


def save(ckpt: Checkpoint, directory: str) -> str:
    """Write ``step_<n>.ckpt`` atomically into ``directory``; returns the
    final path."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"step_{ckpt.step:08d}.ckpt")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as fh:
            pickle.dump(ckpt, fh, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def load(path: str) -> Checkpoint:
    with open(path, "rb") as fh:
        ckpt = pickle.load(fh)
    if not isinstance(ckpt, Checkpoint):
        raise ValueError(f"{path} is not a repro checkpoint file")
    return ckpt


def latest(directory: str) -> Optional[str]:
    """Path of the highest-step checkpoint in ``directory`` (None if no
    checkpoints were written)."""
    if not os.path.isdir(directory):
        return None
    files = sorted(f for f in os.listdir(directory) if f.endswith(".ckpt"))
    return os.path.join(directory, files[-1]) if files else None
