"""VertexProgram — a vertex-centric program as a first-class value.

The paper's thesis (§III–V) is that the *channel interface* is the unit
programmers compose; this module makes the same move one level up: a
whole vertex program — its initial state, its superstep, the channels it
declares, and how to read its answer back out — is a plain immutable
value that can be stored in a registry, handed to an
:class:`~repro.pregel.engine.Engine`, compiled once, and replayed across
runs and same-shape graphs. Algorithm modules export
``program(variant=..., **knobs) -> VertexProgram`` factories; the
central registry (``repro.algorithms.REGISTRY``) and the ``python -m
repro`` CLI are built on top of those factories.

A program is *graph-shape agnostic*: ``init`` may read any host-side
graph metadata (``pg.n``, ``pg.new_of_old`` …) to build the initial
state, but ``step`` must depend on the graph only through its traced
shard argument — that is what lets one compiled executable serve every
graph with the same shape signature.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Tuple

from repro.graph.pgraph import PartitionedGraph


def _identity_extract(pg: PartitionedGraph, state: Any) -> Any:
    return state


@dataclasses.dataclass(eq=False)
class VertexProgram:
    """A declarative vertex-centric program.

    name: stable identifier, conventionally ``"<algorithm>:<variant>"``.
    init: ``init(pg) -> state0`` — per-vertex pytree with leading
      ``(W, n_loc)`` leaves. May close over problem inputs (a SSSP
      source, a pointer-jumping forest, …).
    step: ``step(ctx, graph_shard, state_shard, step_idx)`` returning
      ``(new_state, halt)`` or ``(new_state, halt, overflow)`` — exactly
      the :func:`repro.pregel.runtime.run_supersteps` contract.
    extract: ``extract(pg, final_state) -> output`` — the user-facing
      answer (e.g. global labels in old-id space). Stored on
      ``RunResult.output``.
    channels: optional explicit channel declaration (stat-key names, a
      composed channel with ``channel_names()``, or a mixed sequence).
      Declared programs skip the runtime's eval_shape dry trace.
    query_init: optional ``query_init(pg, query) -> state0`` — the
      query-parametric init that makes the program *batchable*:
      ``Engine.run_batch(prog, pg, queries)`` stacks one state per query
      along a query axis and advances all of them in one compiled loop
      (the bound ``init`` stays the single-query default). ``step`` and
      ``extract`` need no batch awareness — the runtime vmaps the step
      over queries and extract is applied per query slice.
    max_steps: default superstep budget (overridable per run).
    check_overflow: whether capacity overflow aborts the run.
    meta: free-form introspection data — the registry stores the
      algorithm, variant and knobs here; nothing in the runtime reads it.

    Programs hash by identity (``eq=False``): an Engine keys its compile
    cache on the program *object*, so reuse the same instance — e.g. via
    ``repro.algorithms.get_program`` — to reuse its compilations.
    """

    name: str
    init: Callable[[PartitionedGraph], Any]
    step: Callable
    extract: Callable[[PartitionedGraph, Any], Any] = _identity_extract
    channels: Optional[Any] = None
    query_init: Optional[Callable[[PartitionedGraph, Any], Any]] = None
    max_steps: int = 10_000
    check_overflow: bool = True
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def channel_names(self) -> Tuple[str, ...]:
        """The declared stat-key set ('()' when relying on discovery)."""
        if self.channels is None:
            return ()
        from repro.core import compose

        return tuple(sorted(compose.channel_names_of(self.channels)))

    def __repr__(self) -> str:  # compact — meta can hold arrays
        chans = ",".join(self.channel_names()) or "<discovered>"
        return (f"VertexProgram({self.name!r}, max_steps={self.max_steps}, "
                f"channels=[{chans}])")
