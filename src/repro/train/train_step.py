"""Train step: mixed-precision forward/backward, per-layer remat,
gradient accumulation over microbatches (lax.scan), AdamW update.

The gradient all-reduce over the data axes is the Aggregator channel of
the paper mapped onto the mesh (XLA emits it from the sharding specs);
gradient compression (bf16 reduction) is selectable — see
distributed.compression.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def cross_entropy(logits, labels, mask=None):
    """Mean CE over valid positions. logits (B,S,V) f32, labels (B,S) i32.

    The gold-logit extraction is a masked reduction (not a gather), so a
    vocab-sharded logits tensor reduces with one small psum instead of an
    all-gather of the full (B,S,V) logits — essential at 150k vocab.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(iota == labels[..., None], logits, 0.0), axis=-1
    )
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_loss_fn(cfg: ModelConfig, *, remat: bool = True,
                 moe_impl: Optional[Callable] = None,
                 unroll: bool = False):
    def loss_fn(params, batch):
        logits, _ = M.forward(cfg, params, batch, remat=remat,
                              moe_impl=moe_impl, unroll=unroll)
        labels = batch["labels"]
        # frontend-prefix positions carry no loss
        prefix = logits.shape[1] - labels.shape[1]
        if prefix:
            logits = logits[:, prefix:]
        return cross_entropy(logits, labels, batch.get("loss_mask"))
    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt: AdamW,
    *,
    microbatches: int = 1,
    remat: bool = True,
    moe_impl: Optional[Callable] = None,
    grad_dtype=jnp.float32,
    unroll: bool = False,
):
    """Returns train_step(state, batch) -> (state, metrics).

    With microbatches > 1, the global batch's leading dim is split and
    gradients are accumulated with a lax.scan — the standard way to fit
    large models: activation memory is one microbatch, not the full batch.
    """
    loss_fn = make_loss_fn(cfg, remat=remat, moe_impl=moe_impl,
                           unroll=unroll)
    vg = jax.value_and_grad(loss_fn)

    def train_step(state: TrainState, batch):
        params = state.params

        if microbatches == 1:
            loss, grads = vg(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches) + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, grad_dtype), params
            )

            def acc(carry, mb):
                g_acc, l_acc = carry
                loss, g = vg(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(grad_dtype), g_acc, g
                )
                return (g_acc, l_acc + loss), None

            (grads, loss), _ = jax.lax.scan(
                acc, (zeros, jnp.float32(0)), micro,
                unroll=microbatches if unroll else 1,
            )
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss / microbatches

        new_params, new_opt, gnorm = opt.update(grads, state.opt, params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt.schedule(new_opt.step)}
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt: AdamW, key) -> TrainState:
    from repro.models import params as P
    params = P.init_params(cfg, key)
    return TrainState(params, opt.init(params))


def train_state_specs(cfg: ModelConfig, opt: AdamW):
    """ShapeDtypeStruct tree of the train state (for the dry-run)."""
    from repro.models import params as P
    pspecs = P.param_specs(cfg)
    return jax.eval_shape(
        lambda p: TrainState(p, opt.init(p)), pspecs
    )
