"""AdamW with optional gradient clipping and bf16 second-moment storage
(a distributed-memory trick: m in fp32, v in bf16 halves optimizer HBM for
<0.1% quality impact — selectable per config)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any  # first moment (params-shaped)
    v: Any  # second moment (params-shaped)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    v_dtype: Optional[str] = None  # e.g. "bfloat16" to halve v memory

    def init(self, params) -> AdamWState:
        vdt = jnp.dtype(self.v_dtype) if self.v_dtype else None
        zeros = lambda p: jnp.zeros_like(p)
        zeros_v = lambda p: jnp.zeros_like(p, dtype=vdt or p.dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros_v, params),
        )

    def schedule(self, step):
        warm = jnp.minimum(step.astype(jnp.float32) / max(self.warmup_steps, 1), 1.0)
        return self.lr * warm

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.schedule(step)

        if self.grad_clip > 0:
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree_util.tree_leaves(grads))
            )
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        else:
            gnorm = jnp.float32(0)

        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            mf = m.astype(jnp.float32) * b1 + g * (1 - b1)
            vf = v.astype(jnp.float32) * b2 + g * g * (1 - b2)
            mhat = mf / bc1
            vhat = vf / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

        out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
        new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
