"""Deterministic, restartable synthetic data pipeline.

Every batch is a pure function of (seed, step) — after a preemption the
pipeline resumes from the checkpointed step with zero coordination, on any
number of hosts (each host slices its shard by host index). This is the
fault-tolerance property a real multi-pod pipeline needs; swapping in a
real tokenized corpus only changes `_tokens_for`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticLM:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int, host_index: int = 0,
                 host_count: int = 1) -> Dict[str, jax.Array]:
        b = self.global_batch // host_count
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), host_index
        )
        # Markov-ish structured stream: next token depends on current
        # (so the LM has something learnable).
        k1, k2 = jax.random.split(key)
        base = jax.random.randint(k1, (b, self.seq_len + 1), 0, self.cfg.vocab)
        drift = jax.random.randint(k2, (b, 1), 1, 17)
        seq = (jnp.cumsum(jnp.ones_like(base), axis=1) * drift + base // 7) % self.cfg.vocab
        tokens = seq[:, :-1].astype(jnp.int32)
        labels = seq[:, 1:].astype(jnp.int32)
        batch = {"tokens": tokens, "labels": labels}
        if self.cfg.frontend == "audio_frames":
            ke = jax.random.fold_in(key, 7)
            batch = {
                "embeds": 0.02 * jax.random.normal(
                    ke, (b, self.seq_len, self.cfg.d_model)),
                "labels": labels,
            }
        elif self.cfg.frontend == "vision_patches":
            ke = jax.random.fold_in(key, 8)
            ft = self.cfg.frontend_tokens
            batch["embeds"] = 0.02 * jax.random.normal(
                ke, (b, ft, self.cfg.d_model))
        return batch


def batch_specs(cfg: ModelConfig, seq_len: int, global_batch: int,
                kind: str = "train", dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    b, s = global_batch, seq_len
    i32 = jnp.int32
    if kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
               "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.frontend == "audio_frames":
            out = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype),
                   "labels": jax.ShapeDtypeStruct((b, s), i32)}
        elif cfg.frontend == "vision_patches":
            ft = cfg.frontend_tokens
            out = {"tokens": jax.ShapeDtypeStruct((b, s - ft), i32),
                   "labels": jax.ShapeDtypeStruct((b, s - ft), i32),
                   "embeds": jax.ShapeDtypeStruct((b, ft, cfg.d_model), dtype)}
        return out
    if kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.frontend == "audio_frames":
            out = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)}
        elif cfg.frontend == "vision_patches":
            ft = cfg.frontend_tokens
            out = {"tokens": jax.ShapeDtypeStruct((b, s - ft), i32),
                   "embeds": jax.ShapeDtypeStruct((b, ft, cfg.d_model), dtype)}
        return out
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    raise ValueError(kind)
