"""Fault-tolerant checkpointing.

- Atomic: writes to <dir>/tmp.<step> then renames to <dir>/step_<n>.
- Sharded: each process saves only its addressable shards (single-process
  here, but the layout is per-process files + a merged manifest, the same
  layout a 1000-host job writes).
- Async: a background thread does the serialization; training continues.
- Elastic: restore() device_puts onto ANY target sharding — a checkpoint
  taken on mesh A restarts on mesh B (different pod count / axis sizes).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def save(ckpt_dir: str, step: int, tree: Any, process_index: int = 0,
         blocking: bool = True) -> Optional[threading.Thread]:
    """Save a pytree checkpoint. Returns the writer thread if async."""
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    host_leaves = [(_path_str(p), np.asarray(v)) for p, v in leaves_with_paths]

    def write():
        tmp = os.path.join(ckpt_dir, f"tmp.{step}.{process_index}")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        arrays = {k: v for k, v in host_leaves}
        np.savez(os.path.join(tmp, f"shard_{process_index}.npz"), **arrays)
        manifest = {
            "step": step,
            "leaves": [
                {"path": k, "shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in host_leaves
            ],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None,
            shardings: Any = None, process_index: int = 0) -> Any:
    """Restore into the structure of `target`; device_put with `shardings`
    if given (elastic resharding onto a new mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, f"shard_{process_index}.npz"))
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for p, tgt in leaves_with_paths:
        key = _path_str(p)
        arr = data[key]
        assert tuple(arr.shape) == tuple(tgt.shape), (key, arr.shape, tgt.shape)
        out.append(jnp.asarray(arr, dtype=tgt.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
