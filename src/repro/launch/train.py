"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 200 --seq-len 256 --global-batch 8 --smoke \
        --ckpt-dir /tmp/ckpt [--resume]

On a real cluster this runs once per host (jax.distributed.initialize is
called when JAX_COORDINATOR is set); on CPU it drives the same code on one
process. Checkpoint/restart, straggler monitoring, deterministic data
resume, and gradient compression are all on by default.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '16x16' or '2x16x16' (default: single device)")
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()  # multi-host entry

    from repro.configs import registry
    from repro.distributed import sharding as sh
    from repro.distributed.context import activation_sharding
    from repro.distributed.fault_tolerance import (StragglerMonitor,
                                                   TrainSupervisor)
    from repro.launch.mesh import make_mesh
    from repro.train import data as data_lib
    from repro.train import train_step as ts
    from repro.train.optimizer import AdamW

    spec = registry.ARCHS[args.arch]
    cfg = spec.smoke if args.smoke else spec.config
    opt = AdamW(lr=args.lr)
    pipe = data_lib.SyntheticLM(cfg, args.seq_len, args.global_batch,
                                seed=args.seed)

    step_fn = ts.make_train_step(cfg, opt, microbatches=args.microbatches,
                                 remat=True)

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, axes)
        state_sh = sh.named(mesh, sh.train_state_pspecs(cfg, mesh))
        jitted = jax.jit(step_fn, in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        ctx = activation_sharding(mesh)
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0,))
        import contextlib
        ctx = contextlib.nullcontext()
        state_sh = None

    sup = None
    start = 0
    init_fn = lambda: ts.init_train_state(cfg, opt, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        sup = TrainSupervisor(args.ckpt_dir, save_every=args.save_every)
        sup.install_preemption_handler()
        state, start = sup.restore_or(init_fn, shardings=state_sh)
        if start:
            print(f"[train] resumed from step {start}")
    else:
        state = init_fn()

    mon = StragglerMonitor(
        on_straggler=lambda s, t, m: print(
            f"[straggler] step {s}: {t:.3f}s vs median {m:.3f}s")
    )

    nparams = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"[train] {cfg.name}: {nparams/1e6:.1f}M params, "
          f"{args.global_batch}x{args.seq_len} tokens/step, "
          f"steps {start}..{args.steps}")

    with ctx:
        losses = []
        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = pipe.batch_at(step, jax.process_index(),
                                  jax.process_count())
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            mon.record(step, dt)
            if step % args.log_every == 0:
                tok_s = args.global_batch * args.seq_len / dt
                print(f"  step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"{dt*1e3:7.1f} ms/step {tok_s:10.0f} tok/s")
            if sup:
                sup.maybe_save(step, state)
                if sup.preempted:
                    print("[train] preempted — final checkpoint written")
                    break
        if sup:
            sup.finalize(min(step, args.steps - 1), state)

    print(f"[train] done. loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(median step {mon.median*1e3:.1f} ms, "
          f"straggler flags {mon.flags})")


if __name__ == "__main__":
    main()
