"""Production mesh builders (function, not module-level constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def _auto_kwargs(n):
    """axis_types=Auto where the jax version has it (>=0.5), else nothing
    (pre-AxisType versions are implicitly all-auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (TPU v5e); multi_pod adds the 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kwargs(len(axes)))


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_auto_kwargs(len(axes)))


def make_local_mesh(model: int = 1):
    """Single-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"), **_auto_kwargs(2))
