import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct stand-ins (no allocation), print
memory_analysis / cost_analysis, and extract the collective schedule for
the roofline (benchmarks/roofline.py reads the JSON this writes).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-130m \
      --shape train_4k [--multi-pod] [--all] [--out results/dryrun]
"""  # noqa: E402

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.configs.shapes import ALL_SHAPES  # noqa: E402
from repro.distributed import sharding as sh  # noqa: E402
from repro.distributed.context import activation_sharding  # noqa: E402
from repro.distributed.moe_spmd import make_spmd_moe  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models import params as Pm  # noqa: E402
from repro.serve import decode as serve  # noqa: E402
from repro.train import data as data_lib  # noqa: E402
from repro.train import train_step as ts  # noqa: E402
from repro.train.optimizer import AdamW  # noqa: E402

COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*([a-z0-9]+\[[^\]]*\])[^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
TYPE_RE = re.compile(r"([a-z][a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def type_bytes(tstr: str) -> int:
    m = TYPE_RE.match(tstr)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dt, 4)


def collective_stats(hlo_text: str):
    """Per-op-kind output bytes of collectives in the per-device program.

    The compiled module is the per-partition program, so shapes are
    per-device — i.e. bytes that touch this device's links (all-reduce
    moves ~2x in a ring; reported raw, the roofline applies the factor).
    """
    out = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # count start/op once
        _, out_type, kind = m.groups()
        # tuple outputs: sum all leaf types on the lhs
        nbytes = type_bytes(out_type)
        if out_type.startswith("("):
            nbytes = sum(type_bytes(t) for t in TYPE_RE.findall(out_type))
        ent = out.setdefault(kind, {"count": 0, "bytes": 0})
        ent["count"] += 1
        ent["bytes"] += nbytes
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               fsdp: bool = True, donate: bool = True,
               analysis: bool = False):
    """analysis=True re-lowers with scans UNROLLED so cost_analysis and the
    collective schedule count every layer (XLA counts while-loop bodies
    once); used for the roofline, single-pod only."""
    spec = registry.ARCHS[arch]
    cfg = spec.config
    shape = ALL_SHAPES[shape_name]
    skip = registry.shape_applicable(arch, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "multi_pod": multi_pod, "skipped": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    moe_impl = make_spmd_moe(cfg, mesh) if cfg.moe_experts else None
    t0 = time.time()

    if shape.kind == "train":
        opt = AdamW()
        step = ts.make_train_step(cfg, opt, microbatches=spec.train_microbatches,
                                  remat=True, moe_impl=moe_impl,
                                  unroll=analysis)
        state_sds = ts.train_state_specs(cfg, opt)
        batch_sds = data_lib.batch_specs(cfg, shape.seq_len, shape.global_batch,
                                         "train")
        state_sh = sh.named(mesh, sh.train_state_pspecs(cfg, mesh, fsdp=fsdp))
        batch_sh = sh.named(mesh, sh.batch_pspecs(cfg, mesh, batch_sds,
                                                  shape.global_batch))
        metrics_sh = {"loss": sh.named(mesh, jax.sharding.PartitionSpec()),
                      "grad_norm": sh.named(mesh, jax.sharding.PartitionSpec()),
                      "lr": sh.named(mesh, jax.sharding.PartitionSpec())}
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, metrics_sh),
            donate_argnums=(0,) if donate else (),
        )
        with activation_sharding(mesh):
            lowered = jitted.lower(state_sds, batch_sds)
    else:
        pdtype = jnp.dtype(cfg.dtype)  # serving keeps bf16 params
        param_sds = Pm.param_specs(cfg, dtype=pdtype)
        param_sh = sh.named(mesh, sh.param_pspecs(cfg, mesh, fsdp=False))
        cache_sds = M.cache_specs(cfg, shape.global_batch, shape.seq_len)
        cache_sh = sh.named(
            mesh, sh.cache_pspecs(cfg, mesh, cache_sds, shape.global_batch))
        P = jax.sharding.PartitionSpec
        if shape.kind == "prefill":
            step = serve.make_prefill_step(cfg, moe_impl=moe_impl,
                                           unroll=analysis)
            batch_sds = data_lib.batch_specs(cfg, shape.seq_len,
                                             shape.global_batch, "prefill")
            batch_sh = sh.named(mesh, sh.batch_pspecs(cfg, mesh, batch_sds,
                                                      shape.global_batch))
            dpa = sh.dp_axes(mesh)
            ok = shape.global_batch % sh.axis_size(mesh, dpa) == 0
            vok = cfg.vocab % mesh.shape["model"] == 0
            logits_sh = sh.named(
                mesh, P(dpa if ok else None, "model" if vok else None))
            jitted = jax.jit(step, in_shardings=(param_sh, batch_sh, cache_sh),
                             out_shardings=(logits_sh, cache_sh),
                             donate_argnums=(2,) if donate else ())
            with activation_sharding(mesh):
                lowered = jitted.lower(param_sds, batch_sds, cache_sds)
        elif shape.kind == "decode":
            step = serve.make_decode_step(cfg, moe_impl=moe_impl,
                                          unroll=analysis)
            b = shape.global_batch
            tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            dpa = sh.dp_axes(mesh)
            ok = b % sh.axis_size(mesh, dpa) == 0
            tok_sh = sh.named(mesh, P(dpa if ok else None, None))
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            rng_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
            repl = sh.named(mesh, P())
            vok = cfg.vocab % mesh.shape["model"] == 0
            logits_sh = sh.named(
                mesh, P(dpa if ok else None, "model" if vok else None))
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, cache_sh, tok_sh, repl, repl),
                out_shardings=(tok_sh, logits_sh, cache_sh),
                donate_argnums=(1,) if donate else (),
            )
            with activation_sharding(mesh):
                lowered = jitted.lower(param_sds, cache_sds, tok_sds, pos_sds,
                                       rng_sds)
        else:
            raise ValueError(shape.kind)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    result = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "analysis": analysis,
        "mesh": dict(mesh.shape),
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1)) if cost else -1,
        "bytes_accessed": float(cost.get("bytes accessed", -1)) if cost else -1,
        "collectives": coll,
        "params": cfg.num_params(),
        "active_params": cfg.active_params(),
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        try:
            result[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    return result


def analyze_cell(arch: str, shape_name: str, fsdp: bool = True,
                 microbatches: int = 1):
    """Exact roofline counts via depth extrapolation: lower the model at
    1 and 2 blocks with scans UNROLLED (XLA counts while bodies once), and
    extend linearly to the full depth: total = f1 + (f2 - f1)*(NB - 1).
    Single-pod, microbatches=1 (the roofline baseline; grad-accum scales
    only the FSDP weight-gather term — discussed in EXPERIMENTS §Perf)."""
    import dataclasses as dc

    spec = registry.ARCHS[arch]
    cfg_full = spec.config
    shape = ALL_SHAPES[shape_name]
    skip = registry.shape_applicable(arch, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "analysis": True,
                "multi_pod": False, "skipped": skip}

    pat = len(cfg_full.block_pattern())
    nb_full = cfg_full.n_blocks
    sub = {}
    for nb in (1, 2):
        cfg = dc.replace(cfg_full, n_layers=pat * nb)
        mesh = make_production_mesh(multi_pod=False)
        moe_impl = make_spmd_moe(cfg, mesh) if cfg.moe_experts else None
        if shape.kind == "train":
            opt = AdamW()
            step = ts.make_train_step(cfg, opt, microbatches=microbatches,
                                      remat=True, moe_impl=moe_impl,
                                      unroll=True)
            state_sds = ts.train_state_specs(cfg, opt)
            batch_sds = data_lib.batch_specs(cfg, shape.seq_len,
                                             shape.global_batch, "train")
            state_sh = sh.named(mesh, sh.train_state_pspecs(cfg, mesh,
                                                            fsdp=fsdp))
            batch_sh = sh.named(mesh, sh.batch_pspecs(cfg, mesh, batch_sds,
                                                      shape.global_batch))
            P = jax.sharding.PartitionSpec
            msh = {k: sh.named(mesh, P()) for k in
                   ("loss", "grad_norm", "lr")}
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, msh),
                             donate_argnums=(0,))
            with activation_sharding(mesh):
                compiled = jitted.lower(state_sds, batch_sds).compile()
        else:
            pdtype = jnp.dtype(cfg.dtype)
            param_sds = Pm.param_specs(cfg, dtype=pdtype)
            param_sh = sh.named(mesh, sh.param_pspecs(cfg, mesh, fsdp=False))
            cache_sds = M.cache_specs(cfg, shape.global_batch, shape.seq_len)
            cache_sh = sh.named(mesh, sh.cache_pspecs(cfg, mesh, cache_sds,
                                                      shape.global_batch))
            P = jax.sharding.PartitionSpec
            dpa = sh.dp_axes(mesh)
            ok = shape.global_batch % sh.axis_size(mesh, dpa) == 0
            vok = cfg.vocab % mesh.shape["model"] == 0
            logits_sh = sh.named(
                mesh, P(dpa if ok else None, "model" if vok else None))
            if shape.kind == "prefill":
                step = serve.make_prefill_step(cfg, moe_impl=moe_impl,
                                               unroll=True)
                batch_sds = data_lib.batch_specs(cfg, shape.seq_len,
                                                 shape.global_batch,
                                                 "prefill")
                batch_sh = sh.named(mesh, sh.batch_pspecs(
                    cfg, mesh, batch_sds, shape.global_batch))
                jitted = jax.jit(step,
                                 in_shardings=(param_sh, batch_sh, cache_sh),
                                 out_shardings=(logits_sh, cache_sh),
                                 donate_argnums=(2,))
                with activation_sharding(mesh):
                    compiled = jitted.lower(param_sds, batch_sds,
                                            cache_sds).compile()
            else:
                step = serve.make_decode_step(cfg, moe_impl=moe_impl,
                                              unroll=True)
                b = shape.global_batch
                tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
                tok_sh = sh.named(mesh, P(dpa if ok else None, None))
                repl = sh.named(mesh, P())
                jitted = jax.jit(
                    step,
                    in_shardings=(param_sh, cache_sh, tok_sh, repl, repl),
                    out_shardings=(tok_sh, logits_sh, cache_sh),
                    donate_argnums=(1,))
                with activation_sharding(mesh):
                    compiled = jitted.lower(
                        param_sds, cache_sds, tok_sds,
                        jax.ShapeDtypeStruct((), jnp.int32),
                        jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
        cost = compiled.cost_analysis()
        sub[nb] = {
            "flops": float(cost.get("flops", 0)),
            "bytes": float(cost.get("bytes accessed", 0)),
            "coll": collective_stats(compiled.as_text()),
        }

    def extrap(v1, v2):
        # per-block delta clamped at 0 (XLA may optimize the 1-block
        # program differently; never extrapolate negative)
        return v1 + max(v2 - v1, 0) * (nb_full - 1)

    coll = {}
    kinds = set(sub[1]["coll"]) | set(sub[2]["coll"])
    for k in kinds:
        c1 = sub[1]["coll"].get(k, {"count": 0, "bytes": 0})
        c2 = sub[2]["coll"].get(k, {"count": 0, "bytes": 0})
        coll[k] = {"count": int(extrap(c1["count"], c2["count"])),
                   "bytes": int(extrap(c1["bytes"], c2["bytes"]))}

    return {
        "arch": arch,
        "shape": shape_name,
        "analysis": True,
        "multi_pod": False,
        "mesh": {"data": 16, "model": 16},
        "kind": shape.kind,
        "flops": extrap(sub[1]["flops"], sub[2]["flops"]),
        "bytes_accessed": extrap(sub[1]["bytes"], sub[2]["bytes"]),
        "collectives": coll,
        "params": cfg_full.num_params(),
        "active_params": cfg_full.active_params(),
        "depth_points": sub,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--analysis", action="store_true",
                    help="unrolled-scan lowering for exact roofline counts")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel residual stream (optimized)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.seq_parallel:
        from repro.distributed import context as dctx
        dctx.DEFAULT_SEQ_PARALLEL = True
    if args.all:
        meshes = ([False] if args.analysis
                  else ([False, True] if args.both_meshes
                        else [args.multi_pod]))
        cells = [(a, s, mp)
                 for a, shape, _ in registry.cells()
                 for s in [shape.name]
                 for mp in meshes]
    else:
        assert args.arch and args.shape
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
        if args.analysis:
            tag += "__analysis"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[cached] {tag}")
            continue
        print(f"[lower+compile] {tag} ...", flush=True)
        try:
            if args.analysis:
                res = analyze_cell(arch, shape, fsdp=not args.no_fsdp)
            else:
                res = lower_cell(arch, shape, mp, fsdp=not args.no_fsdp)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            if "skipped" in res:
                print(f"  -> SKIP: {res['skipped']}")
            else:
                print(f"  -> ok: compile {res.get('compile_s', '-')}s, "
                      f"flops {res['flops']:.3e}, "
                      f"colls { {k: v['count'] for k, v in res['collectives'].items()} }")
        except Exception as e:
            failures += 1
            print(f"  -> FAIL: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
