"""Activation-sharding context.

The model code stays mesh-agnostic; the launcher (dry-run / trainer)
activates this context while TRACING so that `constrain()` pins the few
activation shardings GSPMD gets wrong on its own (notably: keep logits
vocab-sharded through the loss instead of all-gathering (B,S,V)).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    dp: Tuple[str, ...]   # data-parallel axes ("pod","data") / ("data",)
    tp: str = "model"
    seq_parallel: bool = False  # shard the residual stream's seq dim on tp


def current() -> Optional[ShardCtx]:
    return getattr(_TLS, "ctx", None)


DEFAULT_SEQ_PARALLEL = False  # flipped by launchers (--seq-parallel)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, seq_parallel=None):
    if seq_parallel is None:
        seq_parallel = DEFAULT_SEQ_PARALLEL
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = ShardCtx(mesh=mesh, dp=dp, seq_parallel=seq_parallel)
    try:
        yield _TLS.ctx
    finally:
        _TLS.ctx = prev


def constrain(x, *logical):
    """logical entries: 'dp' (batch), 'tp' (model axis), None. Only applies
    to dims that divide the axis size; no-op outside the context."""
    ctx = current()
    if ctx is None:
        return x
    axes = []
    for dim, l in zip(x.shape, logical):
        if l == "dp":
            import numpy as np
            n = int(np.prod([ctx.mesh.shape[a] for a in ctx.dp]))
            axes.append(ctx.dp if dim % n == 0 else None)
        elif l == "tp":
            axes.append(ctx.tp if dim % ctx.mesh.shape[ctx.tp] == 0 else None)
        else:
            axes.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*axes))
    )


def residual_spec():
    """Logical spec for the (B, S, D) residual stream: seq-parallel shards
    the sequence dim over the model axis (Megatron-SP — norms/residuals
    compute on 1/TP of the tokens and the TP all-reduce becomes
    reduce-scatter + all-gather pairs placed by XLA)."""
    ctx = current()
    if ctx is not None and ctx.seq_parallel:
        return ("dp", "tp", None)
    return ("dp", None, None)
