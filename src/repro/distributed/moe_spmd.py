"""SPMD MoE: the routed-expert layer as an explicit shard_map channel.

Tokens stay local to their data shard (request dedup/sort is shard-local),
experts live on the model axis (EP) or are ff-sliced across it (expert-TP
when the expert count doesn't divide the axis). Each model shard computes
only its share and the outputs combine with one psum over "model" — the
request-respond channel pattern lowered to a single mesh collective,
instead of letting GSPMD emit a global all-gather+sort for the dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as sh
from repro.models import layers
from repro.models.config import ModelConfig


def make_spmd_moe(cfg: ModelConfig, mesh: Mesh):
    ep = sh.ep_enabled(cfg, mesh)
    dp = sh.dp_axes(mesh)
    m = mesh.shape["model"]
    all_axes = tuple(mesh.axis_names)

    if ep:
        w1_spec = P("model", None, None)
        w2_spec = P("model", None, None)
        e_loc = cfg.moe_experts // m
    else:
        w1_spec = P(None, None, "model")
        w2_spec = P(None, "model", None)
        e_loc = cfg.moe_experts

    def routed(lp_r, x):
        b, s, d = x.shape
        x_spec = P(dp) if b % sh.axis_size(mesh, dp) == 0 else P()

        def local(router, w1, w2, w3, xs):
            bl, sl, _ = xs.shape
            lo = jax.lax.axis_index("model") * e_loc if ep else 0
            lp_local = {"router": router, "moe_w1": w1, "moe_w2": w2}
            if w3 is not None:
                lp_local["moe_w3"] = w3
            y = layers.moe_local(
                cfg, lp_local, xs.reshape(bl * sl, d),
                expert_lo=lo, n_local_experts=w1.shape[0],
            )
            y = jax.lax.psum(y, "model")
            return y.reshape(bl, sl, d)

        w3 = lp_r.get("moe_w3")
        return jax.shard_map(
            local,
            mesh=mesh,
            in_specs=(P(), w1_spec, w2_spec,
                      None if w3 is None else w1_spec, x_spec),
            out_specs=x_spec,
            check_vma=False,
        )(lp_r["router"], lp_r["moe_w1"], lp_r["moe_w2"], w3, x)

    def moe_impl(cfg_, lp, x):
        y = routed(lp, x)
        if cfg_.moe_shared_ff:
            shared = layers.dense_mlp(
                cfg_, lp["shared_w1"], lp["shared_w2"],
                lp.get("shared_w3"), x)
            gate = jax.nn.sigmoid((x @ lp["shared_gate"]).astype(jnp.float32))
            y = y + shared * gate.astype(x.dtype)
        return y

    return moe_impl
