"""Fault tolerance & straggler mitigation for 1000+ node operation.

- StragglerMonitor: per-step time tracker with robust outlier detection;
  at pod scale the policy hook triggers checkpoint-and-evict for hosts
  whose step times degrade persistently (ICI/HBM faults degrade slowly
  before they fail hard).
- TrainSupervisor: wraps the train loop with checkpoint/restart —
  periodic async checkpoints, crash-window replay from the deterministic
  data pipeline (batches are pure functions of step), and preemption-safe
  final checkpoint.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    """Flags steps (or hosts, when fed per-host times) that exceed
    median * threshold over a sliding window."""

    window: int = 50
    threshold: float = 1.75
    min_samples: int = 10
    times: List[float] = dataclasses.field(default_factory=list)
    flags: int = 0
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window:]
        if len(hist) < self.min_samples:
            return False
        med = float(np.median(hist[:-1]))
        is_straggler = seconds > self.threshold * med
        if is_straggler:
            self.flags += 1
            if self.on_straggler:
                self.on_straggler(step, seconds, med)
        return is_straggler

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


@dataclasses.dataclass
class TrainSupervisor:
    """Checkpoint/restart supervisor around a step function.

    Usage:
        sup = TrainSupervisor(ckpt_dir, save_every=100)
        state, start = sup.restore_or(init_fn, target_specs, shardings)
        for step in range(start, total):
            state, metrics = train_step(state, pipe.batch_at(step))
            sup.maybe_save(step, state)
    """

    ckpt_dir: str
    save_every: int = 100
    async_save: bool = True
    keep_last: int = 3
    _pending: Optional[object] = None
    _preempted: bool = False

    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    def restore_or(self, init_fn, target=None, shardings=None):
        """Returns (state, start_step). Restores the newest checkpoint if
        one exists (onto the CURRENT mesh via `shardings` — elastic)."""
        from repro.train import checkpoint as ckpt
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return init_fn(), 0
        tgt = target if target is not None else init_fn()
        state = ckpt.restore(self.ckpt_dir, tgt, step=step,
                             shardings=shardings)
        return state, step + 1

    def maybe_save(self, step: int, state, force: bool = False):
        from repro.train import checkpoint as ckpt
        due = force or self._preempted or (
            step > 0 and step % self.save_every == 0)
        if not due:
            return False
        if self._pending is not None:
            self._pending.join()  # one in-flight save at a time
        self._pending = ckpt.save(self.ckpt_dir, step, state,
                                  blocking=not self.async_save)
        self._gc()
        return True

    def finalize(self, step: int, state):
        if self._pending is not None:
            self._pending.join()
        from repro.train import checkpoint as ckpt
        ckpt.save(self.ckpt_dir, step, state, blocking=True)

    def _gc(self):
        import os
        import shutil
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_")
        )
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)

    @property
    def preempted(self) -> bool:
        return self._preempted
