"""Sharding policy: logical parameter axes -> mesh PartitionSpecs.

Mesh axes: ("pod",) "data", "model".
  - fsdp: weight dim sharded over all data-parallel axes (ZeRO-3);
  - tp:   weight dim sharded over the model axis;
  - ep:   expert dim over the model axis when the expert count divides it,
          otherwise experts stay replicated and their ff dim ("etp") takes
          the model axis (expert-internal tensor parallelism) — this keeps
          e.g. Mixtral's 8 experts valid on a 16-way model axis.

Activations: batch over the data axes; KV cache prefers kv-heads over the
model axis, falling back to the sequence dim when kv-heads don't divide it
(GQA with few kv heads, e.g. chatglm3's kv=2) — the sequence-parallel
decode path (partial attention + XLA-inserted softmax collectives).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import params as Pm
from repro.models.config import ModelConfig


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def ep_enabled(cfg: ModelConfig, mesh: Mesh) -> bool:
    m = mesh.shape["model"]
    return cfg.moe_experts > 0 and cfg.moe_experts % m == 0


def param_pspecs(cfg: ModelConfig, mesh: Mesh, fsdp: bool = True):
    """PartitionSpec tree matching the param tree."""
    ep = ep_enabled(cfg, mesh)
    dp = dp_axes(mesh)

    def to_mesh_axes(logical):
        if logical == "fsdp":
            return dp if fsdp else None
        if logical == "tp":
            return "model"
        if logical == "ep":
            return "model" if ep else None
        if logical == "etp":
            return None if ep else "model"
        return None

    axes_tree = Pm.param_axes(cfg)
    shapes_tree = Pm.param_specs(cfg)

    def spec(axes, sds):
        mesh_axes = []
        for dim, logical in zip(sds.shape, axes):
            ma = to_mesh_axes(logical)
            if ma is not None and dim % axis_size(mesh, ma) != 0:
                ma = None  # don't shard indivisible dims (explicit > padded)
            mesh_axes.append(ma)
        while mesh_axes and mesh_axes[-1] is None:
            mesh_axes.pop()
        return P(*mesh_axes)

    return jax.tree_util.tree_map(
        spec, axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_specs_tree,
                 global_batch: int):
    """Input batch sharding: leading batch dim over the data axes."""
    dp = dp_axes(mesh)
    dp_n = axis_size(mesh, dp)
    baxes = dp if global_batch % dp_n == 0 else (
        dp[-1] if global_batch % mesh.shape[dp[-1]] == 0 else None)

    def spec(sds):
        if sds.ndim == 0:
            return P()
        return P(baxes)

    return jax.tree_util.tree_map(spec, batch_specs_tree)


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_specs_tree,
                 batch: int):
    """Decode-cache sharding (leaves stacked (nb, B, ...))."""
    dp = dp_axes(mesh)
    dp_n = axis_size(mesh, dp)
    m = mesh.shape["model"]
    baxes = dp if batch % dp_n == 0 else None
    kv_heads_shardable = cfg.n_kv_heads % m == 0

    def spec_path(path, sds):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v"):
            # (nb, B, S, Hkv, hd)
            if kv_heads_shardable:
                return P(None, baxes, None, "model", None)
            s = sds.shape[2]
            seq_ax = "model" if s % m == 0 else None
            if baxes is None and seq_ax is not None and s % (m * dp_n) == 0:
                # long-context decode: sequence-parallel over data+model
                return P(None, None, (*dp, "model"), None, None)
            return P(None, baxes, seq_ax, None, None)
        if name == "ssm":
            # (nb, B, H, P, N)
            h = sds.shape[2]
            return P(None, baxes, "model" if h % m == 0 else None, None, None)
        if name in ("conv_x",):
            c = sds.shape[-1]
            return P(None, baxes, None, "model" if c % m == 0 else None)
        return P(None, baxes)

    return jax.tree_util.tree_map_with_path(spec_path, cache_specs_tree)


def train_state_pspecs(cfg: ModelConfig, mesh: Mesh, fsdp: bool = True):
    """TrainState sharding: params, and m/v like params; step replicated."""
    from repro.train.train_step import TrainState
    from repro.train.optimizer import AdamWState
    p = param_pspecs(cfg, mesh, fsdp=fsdp)
    return TrainState(
        params=p,
        opt=AdamWState(step=P(), m=p, v=p),
    )


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
