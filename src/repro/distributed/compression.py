"""Gradient compression — the Aggregator channel's optimized variant.

The paper's point applied to training: the gradient all-reduce is one
typed channel, so its wire format can be optimized independently of the
rest of the program. bf16 compression halves the DP all-reduce bytes
(the dominant collective for FSDP training); error feedback keeps the
fp32 master-accumulation unbiased across steps.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # error-feedback residual, params-shaped (or None)


def init_state(params, error_feedback: bool = True) -> CompressionState:
    if not error_feedback:
        return CompressionState(error=None)
    return CompressionState(
        error=jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    )


def compress_grads(grads, state: CompressionState, dtype=jnp.bfloat16):
    """Quantize grads to `dtype` with error feedback. Returns
    (compressed_grads, new_state). Apply BEFORE the step's psum/update so
    the all-reduce moves half the bytes."""
    if state.error is None:
        comp = jax.tree_util.tree_map(lambda g: g.astype(dtype), grads)
        return comp, state

    def comp_one(g, e):
        gf = g.astype(jnp.float32) + e
        q = gf.astype(dtype)
        new_e = gf - q.astype(jnp.float32)
        return q, new_e

    out = jax.tree_util.tree_map(comp_one, grads, state.error)
    comp = jax.tree_util.tree_map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
    return comp, CompressionState(error=err)


def decompress_grads(grads):
    return jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
