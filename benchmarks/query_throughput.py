"""Query-throughput benchmark: one batched multi-query loop vs a serial
per-query loop.

    PYTHONPATH=src python -m benchmarks.query_throughput [--scale 12]
        [--queries 32] [--out BENCH_query_throughput.json]

The serving question behind the ROADMAP's batching axis: given Q
independent queries of one program (Q SSSP landmark sources, Q
reachability roots, Q personalization vertices), how many queries per
second does one worker fleet answer? Two executions of the *same*
program are compared, both through one warm ``Engine`` session so no
compile time is ever inside a timed region:

  - serial:  Q ``run_batch(prog, pg, [s])`` calls — one compiled Q=1
    executable replayed per query (compile-cache hits), paying the
    per-run dispatch/readback/extract cost Q times;
  - batched: one ``run_batch(prog, pg, sources)`` call — the query axis
    is vmapped inside the superstep, so every superstep advances all Q
    queries and the per-run cost is paid once.

Per-query outputs are asserted bit-identical between the two before
anything is timed. Results (queries/sec per program plus the
``headline`` speedup, target >= 3x at scale 12 / Q=32) go to
``BENCH_query_throughput.json``; ``scripts/tier1.sh`` runs a small-Q
smoke of this benchmark and schema-checks the artifact.

What the rows show: batching pays off exactly where the channel plan is
*static* — personalized PageRank (ScatterCombine) and propagation-style
SSSP amortize their plan work across the query axis (~3-12x), while the
dynamically *routed* channels (sssp:basic / reach:basic CombinedMessage)
re-pay their per-lane dedup + wire packing per query and land below 1x.
Pick the channel with the query axis in mind — the composition-layer
moral, now measured.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.algorithms import REGISTRY
from repro.graph import pgraph
from repro.pregel.engine import Engine

W = 8
HEADLINE_PROGRAM = "pagerank:personal"
TARGET = 3.0
DEFAULT_KEYS = ("sssp:basic", "sssp:prop", "reach:basic",
                "pagerank:personal")


def _bench_program(key: str, scale: int, q: int, repeats: int):
    spec = REGISTRY[key]
    graph = spec.make_graph(scale, 0)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    sources = spec.queries(graph, 0, q)
    q = len(sources)  # queries() clamps to graph.n — rate by actual Q
    prog = spec.factory(**spec.inputs(graph, 0))
    eng = Engine(mode="fused")

    # warm both executables (batch cap and the Q=1 cap) and check that
    # the batched per-query outputs are bit-identical to the serial loop
    res_b = eng.run_batch(prog, pg, sources)
    serial = [eng.run_batch(prog, pg, [s]) for s in sources]
    for qi in range(len(sources)):
        np.testing.assert_array_equal(
            np.asarray(res_b.outputs[qi]), np.asarray(serial[qi].outputs[0]))
        assert int(res_b.query_steps[qi]) == int(serial[qi].query_steps[0])

    t_batched = min(
        _timed(lambda: eng.run_batch(prog, pg, sources))
        for _ in range(repeats))
    t_serial = min(
        _timed(lambda: [eng.run_batch(prog, pg, [s]) for s in sources])
        for _ in range(repeats))

    row = {
        "graph_n": graph.n,
        "q": q,
        "supersteps_batched": int(res_b.steps),
        "wall_s_batched": t_batched,
        "wall_s_serial": t_serial,
        "queries_per_s_batched": q / t_batched,
        "queries_per_s_serial": q / t_serial,
        "speedup": t_serial / t_batched,
        "outputs_match": True,
        "engine": eng.stats(),
    }
    print(f"  {key:20s} serial {q / t_serial:8.1f} q/s   "
          f"batched {q / t_batched:8.1f} q/s   "
          f"speedup {row['speedup']:6.2f}x")
    return row


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(scale: int = 12, q: int = 32, repeats: int = 3,
        keys=DEFAULT_KEYS):
    out = {"scale": scale, "workers": W, "q": q, "repeats": repeats,
           "mode": "fused", "programs": {}}
    for key in keys:
        out["programs"][key] = _bench_program(key, scale, q, repeats)
    head = out["programs"].get(HEADLINE_PROGRAM,
                               next(iter(out["programs"].values())))
    out["headline"] = {
        "program": HEADLINE_PROGRAM if HEADLINE_PROGRAM in out["programs"]
        else next(iter(out["programs"])),
        "scale": scale,
        "q": q,
        "queries_per_s_serial": head["queries_per_s_serial"],
        "queries_per_s_batched": head["queries_per_s_batched"],
        "speedup": head["speedup"],
        "target": TARGET,
        "meets_target": head["speedup"] >= TARGET,
    }
    print(f"  headline: {out['headline']['program']} "
          f"{out['headline']['speedup']:.2f}x "
          f"(target {TARGET}x) at scale {scale}, Q={q}")
    return out


def run_and_write(scale: int = 12, q: int = 32, repeats: int = 3,
                  keys=DEFAULT_KEYS,
                  out_path: str = "BENCH_query_throughput.json"):
    print(f"== Query throughput (scale {scale}, W={W}, Q={q}) ==")
    out = run(scale, q, repeats, keys)
    from benchmarks import common
    out["provenance"] = common.provenance()
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--keys", default=",".join(DEFAULT_KEYS),
                    help="comma list of batched registry keys")
    ap.add_argument("--out", default="BENCH_query_throughput.json")
    args = ap.parse_args()
    run_and_write(args.scale, args.queries, args.repeats,
                  tuple(args.keys.split(",")), args.out)


if __name__ == "__main__":
    main()
