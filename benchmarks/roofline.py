"""Roofline analysis from the dry-run artifacts (results/dryrun/*.json).

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs / peak_FLOPs          [per chip]
  memory term     = HLO_bytes / HBM_bw              [per chip]
  collective term = collective_bytes / link_bw      [per chip]
The compiled module is the per-partition program, so cost_analysis numbers
are already per chip. all-reduce wire bytes are counted 2x (ring RS+AG).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 4 ICI links
~50 GB/s each (bidirectional, 2D torus) => 100 GB/s usable per chip for
ring collectives on one axis.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9   # per link per direction
LINKS_USED = 2   # ring over one mesh axis uses 2 links (bidirectional ring)

COLLECTIVE_WIRE_FACTOR = {
    "all-reduce": 2.0,        # ring reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def load(out_dir: str = "results/dryrun", prefer_analysis: bool = True):
    """Load cells; when an __analysis artifact exists (unrolled depth-
    extrapolated counts) it replaces the production cell's flops/bytes/
    collectives while keeping the production memory numbers."""
    prod, ana = {}, {}
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        key = (cell["arch"], cell["shape"], cell.get("multi_pod", False))
        if cell.get("analysis"):
            ana[key] = cell
        else:
            prod[key] = cell
    cells = []
    for key, cell in prod.items():
        if prefer_analysis and key in ana and "skipped" not in cell:
            a = ana[key]
            cell = dict(cell)
            cell["flops"] = a["flops"]
            cell["bytes_accessed"] = a["bytes_accessed"]
            cell["collectives"] = a["collectives"]
            cell["exact_counts"] = True
        cells.append(cell)
    return sorted(cells, key=lambda c: (c["arch"], c["shape"],
                                        c.get("multi_pod", False)))


def roofline_terms(cell):
    """Returns dict of the three terms (seconds) + bottleneck + MFU-style
    ratios, or None for skipped cells."""
    if "skipped" in cell:
        return None
    flops = cell["flops"]
    bytes_acc = cell["bytes_accessed"]
    coll_bytes = sum(
        v["bytes"] * COLLECTIVE_WIRE_FACTOR.get(k, 1.0)
        for k, v in cell["collectives"].items()
    )
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_collective = coll_bytes / (LINK_BW * LINKS_USED)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)

    # useful model FLOPs: 6*N_active*D tokens (train: x3 for fwd+bwd)
    n_act = cell["active_params"]
    chips = 1
    for v in cell["mesh"].values():
        chips *= v
    if cell["kind"] == "train":
        tokens = 4096 * 256
        model_flops = 6 * n_act * tokens  # 2 fwd + 4 bwd per param-token
    elif cell["kind"] == "prefill":
        tokens = {"prefill_32k": 32768 * 32}.get(cell["shape"], 0)
        model_flops = 2 * n_act * tokens
    else:  # decode: one token per sequence
        bsz = {"decode_32k": 128, "long_500k": 1}.get(cell["shape"], 1)
        model_flops = 2 * n_act * bsz
    model_flops_per_chip = model_flops / chips

    # decode is bandwidth-bound by construction: the useful-work metric is
    # bytes that MUST move per step (weights once + KV/state read) vs HLO
    # bytes, and the roofline fraction is that ratio against the bound.
    bpp = 2  # bf16 serving
    if cell["kind"] == "decode":
        model_bytes = cell["active_params"] * bpp / chips
        # KV/state read: approximate with the cache argument size
        model_bytes += cell.get("argument_size_in_bytes", 0) * 0.9
        useful = model_bytes / max(bytes_acc, 1)
        bound = max(terms.values())
        return {
            **terms,
            "dominant": dominant.replace("_s", ""),
            "step_time_bound_s": bound,
            "model_flops_per_chip": model_flops_per_chip,
            "useful_flops_ratio": useful,
            "roofline_fraction": (
                (model_bytes / HBM_BW) / bound if bound > 0 else 0),
            "collective_bytes": coll_bytes,
            "decode_bandwidth_metric": True,
        }

    bound = max(terms.values())
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "step_time_bound_s": bound,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flops_ratio": (model_flops_per_chip / flops) if flops > 0 else 0,
        "roofline_fraction": (
            (model_flops_per_chip / PEAK_FLOPS) / bound if bound > 0 else 0
        ),
        "collective_bytes": coll_bytes,
    }


def fmt_table(cells, multi_pod=False):
    rows = []
    hdr = (f"| arch | shape | compute s | memory s | collective s | "
           f"dominant | useful FLOPs | roofline frac |")
    sep = "|" + "---|" * 8
    rows.append(hdr)
    rows.append(sep)
    for c in cells:
        if c.get("multi_pod") != multi_pod:
            continue
        t = roofline_terms(c)
        if t is None:
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"SKIP | — | — |")
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | **{t['dominant']}** "
            f"| {t['useful_flops_ratio']*100:.1f}% "
            f"| {t['roofline_fraction']*100:.1f}% |"
        )
    return "\n".join(rows)


def dryrun_proof_table(cells):
    """Multi-pod dry-run proof: compile success + per-device memory."""
    rows = ["| arch | shape | mesh | compile s | args GB/dev | temps GB/dev |",
            "|---|---|---|---|---|---|"]
    for c in cells:
        if "skipped" in c:
            continue
        mesh = "2x16x16" if c.get("multi_pod") else "16x16"
        rows.append(
            f"| {c['arch']} | {c['shape']} | {mesh} "
            f"| {c.get('compile_s', 0):.1f} "
            f"| {c.get('argument_size_in_bytes', 0)/1e9:.2f} "
            f"| {c.get('temp_size_in_bytes', 0)/1e9:.2f} |")
    return "\n".join(rows)


def main():
    cells = load()
    print(f"loaded {len(cells)} dry-run cells")
    print("\n### Roofline (single-pod 16x16; exact unrolled counts)\n")
    print(fmt_table(cells, multi_pod=False))
    print("\n### Multi-pod dry-run proof (2x16x16 compiles)\n")
    print(dryrun_proof_table([c for c in cells if c.get("multi_pod")]))


if __name__ == "__main__":
    main()
