"""Serving benchmark: continuous lane admission vs drain-then-refill.

    PYTHONPATH=src python -m benchmarks.serving [--scale 12]
        [--queries 128] [--lanes 16] [--out BENCH_serving.json]

The ROADMAP's "millions of users" scenario made concrete: queries of one
program arrive as a seeded Poisson stream and a fixed fleet of query
lanes must answer them. Two schedulers run the *same* workload through
the *same* warm ``Engine`` session:

  - batch (drain-then-refill): the ``run_batch`` discipline — admit up
    to ``lanes`` ready queries, run the batch until its LAST query
    halts, only then admit the next group. Skewed per-query work (a BFS
    from a low-degree root halts in 2 steps, a hub root takes 10+)
    leaves lanes frozen-but-carried for most of the batch.
  - serve (continuous batching): ``Engine.serve`` — at every chunk
    boundary, lanes whose queries halted are harvested and refilled
    from the queue, so the fleet stays full (the LLM-serving trick,
    applied to vertex programs).

Both run the full stream to completion; sustained queries/sec is
N/wall, latency is arrival-to-finish (reported p50/p99 in supersteps —
deterministic — and wall seconds). Every served answer is verified
bit-identical to a solo run *before* anything is timed. The headline
(target >= 1.5x serve over batch at scale 12) plus per-query records
(qid/lane/admitted/finished/steps/output hash — the determinism test's
fixture) go to ``BENCH_serving.json``; ``scripts/tier1.sh`` runs a
small smoke of this benchmark and schema-checks the artifact.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time

import numpy as np

from repro.algorithms import REGISTRY
from repro.graph import pgraph
from repro.pregel.engine import Engine
from repro.pregel.serve import QueryQueue

W = 8
HEADLINE_PROGRAM = "reach:basic"
TARGET = 1.5
DEFAULT_KEYS = ("reach:basic", "sssp:basic")


def _output_hash(output) -> str:
    """Stable content hash of a query's extracted output (array or dict
    of arrays) — lets the JSON carry bit-identity evidence per query."""
    h = hashlib.sha256()
    if isinstance(output, dict):
        for k in sorted(output):
            h.update(k.encode())
            h.update(np.ascontiguousarray(np.asarray(output[k])).tobytes())
    else:
        h.update(np.ascontiguousarray(np.asarray(output)).tobytes())
    return h.hexdigest()[:16]


def _drain_then_refill(eng, prog, pg, schedule, lanes):
    """The run_batch discipline over the same arrival stream: groups of
    up to ``lanes`` ready queries run to the group's slowest halt before
    the next admission. Returns (latencies_in_steps, wall_s)."""
    queue = list(schedule)  # (arrival, qid, query), arrival-sorted
    clock = 0
    lat = {}
    t0 = time.perf_counter()
    while queue:
        ready = [e for e in queue if e[0] <= clock]
        if not ready:
            clock = max(clock, queue[0][0])
            continue
        group = ready[:lanes]
        queue = [e for e in queue if e not in group]
        res = eng.run_batch(prog, pg, [e[2] for e in group])
        clock += int(res.steps)  # the batch holds every lane to its max
        for e in group:
            lat[e[1]] = clock - e[0]
    return lat, time.perf_counter() - t0


def _bench_program(key: str, scale: int, q: int, lanes: int, chunk: int,
                   rate: float, seed: int, repeats: int):
    spec = REGISTRY[key]
    graph = spec.make_graph(scale, seed)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    schedule = spec.stream(graph, seed, q, rate)
    q = len(schedule)  # queries() clamps to graph.n — rate by actual Q
    prog = spec.factory(**spec.inputs(graph, seed))
    eng = Engine(mode="chunked", chunk_size=chunk)

    make_queue = lambda: QueryQueue.from_schedule(schedule)
    # warm both executables, then verify every served answer against a
    # solo run (Q=1 run_batch — pinned bit-identical to Engine.run by
    # the tier-1 suite) before any timed region
    res = eng.serve(prog, pg, make_queue(), num_lanes=lanes)
    for rec in res.records:
        solo = eng.run_batch(prog, pg, [rec.query])
        np.testing.assert_array_equal(np.asarray(rec.output),
                                      np.asarray(solo.outputs[0]))
        assert rec.steps == int(solo.query_steps[0]), rec.qid
        assert rec.bytes_by_channel == solo.query_bytes(0), rec.qid
    sched3 = [(arr, qid, query) for qid, (arr, query) in enumerate(schedule)]
    _drain_then_refill(eng, prog, pg, sched3, lanes)  # warm group caps

    # timed replays, everything warm: min wall over `repeats` identical
    # replays (the records/latency-in-steps are deterministic per replay,
    # so any replay's records stand for all of them)
    res = eng.serve(prog, pg, make_queue(), num_lanes=lanes)
    serve_wall = res.wall_time_s
    batch_lat, batch_wall = _drain_then_refill(eng, prog, pg, sched3, lanes)
    for _ in range(repeats - 1):
        serve_wall = min(
            serve_wall,
            eng.serve(prog, pg, make_queue(), num_lanes=lanes).wall_time_s)
        batch_wall = min(
            batch_wall, _drain_then_refill(eng, prog, pg, sched3, lanes)[1])

    lat = res.latency_summary()
    blat = np.array([batch_lat[r.qid] for r in res.records], np.float64)
    row = {
        "graph_n": graph.n,
        "q": q,
        "lanes": lanes,
        "chunk_size": chunk,
        "rate": rate,
        "supersteps_serve": res.supersteps,
        "dispatches_serve": res.dispatches,
        "wall_s_serve": serve_wall,
        "wall_s_batch": batch_wall,
        "queries_per_s_serve": q / serve_wall,
        "queries_per_s_batch": q / batch_wall,
        "speedup": batch_wall / serve_wall,
        "p50_latency_steps": lat["p50_steps"],
        "p99_latency_steps": lat["p99_steps"],
        "p50_latency_s": lat["p50_wall_s"],
        "p99_latency_s": lat["p99_wall_s"],
        "p50_latency_steps_batch": float(np.percentile(blat, 50)),
        "p99_latency_steps_batch": float(np.percentile(blat, 99)),
        "outputs_match": True,
        "engine": eng.stats(),
        # per-query records: the wall-free subset is deterministic in
        # (seed, schedule) — tests/test_serve.py compares it across
        # processes to pin lane-assignment determinism
        "records": [
            {"qid": r.qid, "lane": r.lane, "arrival": r.arrival,
             "admitted": r.admitted, "finished": r.finished,
             "steps": r.steps, "halted": r.halted,
             "output_hash": _output_hash(r.output)}
            for r in res.records
        ],
    }
    print(f"  {key:20s} batch {row['queries_per_s_batch']:8.1f} q/s   "
          f"serve {row['queries_per_s_serve']:8.1f} q/s   "
          f"speedup {row['speedup']:6.2f}x   "
          f"p50/p99 {lat['p50_steps']:.0f}/{lat['p99_steps']:.0f} steps")
    return row


def run(scale: int = 12, q: int = 128, lanes: int = 16, chunk: int = 1,
        rate: float = 16.0, seed: int = 0, keys=DEFAULT_KEYS,
        repeats: int = 3):
    out = {"scale": scale, "workers": W, "q": q, "lanes": lanes,
           "chunk_size": chunk, "rate": rate, "seed": seed,
           "repeats": repeats, "mode": "chunked", "programs": {}}
    for key in keys:
        out["programs"][key] = _bench_program(key, scale, q, lanes, chunk,
                                              rate, seed, repeats)
    head_key = (HEADLINE_PROGRAM if HEADLINE_PROGRAM in out["programs"]
                else next(iter(out["programs"])))
    head = out["programs"][head_key]
    out["headline"] = {
        "program": head_key,
        "scale": scale,
        "q": head["q"],
        "lanes": lanes,
        "queries_per_s_serve": head["queries_per_s_serve"],
        "queries_per_s_batch": head["queries_per_s_batch"],
        "speedup": head["speedup"],
        "p50_latency_steps": head["p50_latency_steps"],
        "p99_latency_steps": head["p99_latency_steps"],
        "p50_latency_s": head["p50_latency_s"],
        "p99_latency_s": head["p99_latency_s"],
        "target": TARGET,
        "meets_target": head["speedup"] >= TARGET,
    }
    print(f"  headline: {head_key} {head['speedup']:.2f}x "
          f"(target {TARGET}x) at scale {scale}, Q={head['q']}, "
          f"lanes={lanes}")
    return out


def run_and_write(scale: int = 12, q: int = 128, lanes: int = 16,
                  chunk: int = 1, rate: float = 16.0, seed: int = 0,
                  keys=DEFAULT_KEYS, repeats: int = 3,
                  out_path: str = "BENCH_serving.json"):
    print(f"== Serving (scale {scale}, W={W}, Q={q}, lanes={lanes}, "
          f"chunk={chunk}, rate={rate}/step) ==")
    out = run(scale, q, lanes, chunk, rate, seed, keys, repeats)
    from benchmarks import common
    out["provenance"] = common.provenance()
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--lanes", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=1)
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--keys", default=",".join(DEFAULT_KEYS),
                    help="comma list of batched registry keys")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    run_and_write(args.scale, args.queries, args.lanes, args.chunk,
                  args.rate, args.seed, tuple(args.keys.split(",")),
                  args.repeats, args.out)


if __name__ == "__main__":
    main()
