"""One benchmark per paper table (IV, V-top/mid/bottom, VI, VII).

All tables run registry programs (``repro.algorithms.REGISTRY``) through
one shared compile-once ``Engine`` session: a program recurring across
tables on a same-shape graph (e.g. PageRank in Tables IV and V-top, or
any table's repeated mono-vs-basic rows) reuses its executable instead
of re-tracing — ``session_stats()`` reports what the whole sweep paid.
(PJ programs close over their forest, so tree-vs-chain rows are genuinely
different programs; repeated rows on the *same* forest still hit.)
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.algorithms import REGISTRY, get_program
from repro.graph import generators as gen, pgraph
from repro.pregel.engine import Engine

# one compile-once session for every table in a benchmark run
ENGINE = Engine()


def session_stats():
    return ENGINE.stats()


def _run(key: str, pg, **knobs):
    # get_program memoizes array knobs (PJ parents) by identity, so a
    # repeated row on the same forest shares program AND executable
    return ENGINE.run(get_program(key, **knobs), pg)


def _forest(scale: int):
    n = 1 << scale
    empty = gen.EdgeList(n, np.zeros((0, 2), np.int64), None, True, "pj")
    return n, pgraph.partition_graph(empty, common.W, "random", build=())


def table4_basic_channels(scale: int):
    """Table IV: Pregel-monolithic vs channel-typed basic implementations.

    PR/WCC/PJ use a single message type, so Pregel's global combiner
    applies and bytes match (as in the paper); the heterogeneous-message
    algorithms (S-V, MSF) show the combiner-inapplicability / padded-type
    costs that channels remove.
    """
    print("\n== Table IV: basic channels vs monolithic Pregel ==")
    pg_web = common.partitioned("web", scale, "random",
                                REGISTRY["pagerank:basic"].build)
    for name in ("pregel (mono)", "channel (basic)"):
        res = _run("pagerank:basic", pg_web, iters=10)
        common.emit("IV", f"PR {name}", "web", res)

    pg_soc = common.partitioned("social", scale, "random",
                                REGISTRY["wcc:basic"].build)
    for name in ("pregel (mono)", "channel (basic)"):
        res = _run("wcc:basic", pg_soc)
        common.emit("IV", f"WCC {name}", "social", res)

    n, pg_pj = _forest(scale)
    par = gen.parent_chain(n, seed=3)
    for name in ("pregel (mono)", "channel (basic)"):
        res = _run("pj:basic", pg_pj, parents=par)
        common.emit("IV", f"PJ {name}", "chain", res)

    for name, key in (("pregel (mono)", "sv:monolithic"),
                      ("channel (basic)", "sv:basic")):
        res = _run(key, pg_soc)
        common.emit("IV", f"S-V {name}", "social", res)

    pg_w = common.partitioned("weighted", scale - 1, "random",
                              REGISTRY["msf:channels"].build)
    for name, key in (("pregel (mono)", "msf:monolithic"),
                      ("channel (typed)", "msf:channels")):
        res = _run(key, pg_w)
        common.emit("IV", f"MSF {name}", "weighted", res)


def table5_scatter_combine(scale: int):
    """Table V top: PageRank, CombinedMessage vs ScatterCombine channel."""
    print("\n== Table V (top): scatter-combine channel on PageRank ==")
    for ds in ("web", "social_dense"):
        pg = common.partitioned(ds, scale, "random",
                                REGISTRY["pagerank:basic"].build)
        for name, key in (("channel (basic)", "pagerank:basic"),
                          ("channel (scatter)", "pagerank:scatter")):
            res = _run(key, pg, iters=10)
            common.emit("V-top", f"PR {name}", ds, res)


def table5_request_respond(scale: int):
    """Table V middle: Pointer-Jumping, DirectMessage vs RequestRespond."""
    print("\n== Table V (mid): request-respond channel on PJ ==")
    n, pg = _forest(scale)
    for ds, par in [("tree", gen.random_tree_parents(n, seed=5)),
                    ("chain", gen.parent_chain(n, seed=5))]:
        for name, key in (("channel (basic)", "pj:basic"),
                          ("channel (reqresp)", "pj:reqresp")):
            res = _run(key, pg, parents=par)
            common.emit("V-mid", f"PJ {name}", ds, res)


def table5_propagation(scale: int):
    """Table V bottom: WCC, CombinedMessage vs Propagation channel, on the
    unpartitioned (random) and partitioned (bfs/METIS-like) graph."""
    print("\n== Table V (bottom): propagation channel on WCC ==")
    for ds, part, tag in [("road", "random", "road"),
                          ("road", "bfs", "road (P)"),
                          ("social", "random", "social"),
                          ("social", "bfs", "social (P)")]:
        pg = common.partitioned(ds, scale, part, ("prop_out", "raw_out"))
        for name, key in (("channel (basic)", "wcc:basic"),
                          ("channel (prop)", "wcc:prop")):
            res = _run(key, pg)
            extra = {}
            if key == "wcc:prop":
                info = np.asarray(res.state["info"])
                extra = {"global_rounds": int(info[:, 0].max()),
                         "inner_iters": int(info[:, 1].max())}
            common.emit("V-bot", f"WCC {name}", tag, res, extra)


def table6_sv_composition(scale: int):
    """Table VI: S-V with every combination of the two optimized channels."""
    print("\n== Table VI: S-V channel composition ==")
    for ds in ("social", "social_dense"):
        pg = common.partitioned(ds, scale, "random",
                                REGISTRY["sv:basic"].build)
        for name, key in (("2-channel (basic)", "sv:basic"),
                          ("3-channel (reqresp)", "sv:reqresp"),
                          ("4-channel (scatter)", "sv:scatter"),
                          ("5-channel (both)", "sv:both")):
            res = _run(key, pg)
            common.emit("VI", f"S-V {name}", ds, res)


def table7_minlabel_scc(scale: int):
    """Table VII: Min-Label SCC with/without the propagation channel."""
    print("\n== Table VII: Min-Label SCC + propagation channel ==")
    for part, tag in [("random", "web"), ("bfs", "web (P)")]:
        pg = common.partitioned("web", scale, part,
                                REGISTRY["scc:prop"].build)
        for name, key in (("channel (basic)", "scc:basic"),
                          ("channel (prop)", "scc:prop")):
            res = _run(key, pg)
            common.emit("VII", f"SCC {name}", tag, res)


def bonus_sssp(scale: int):
    """SSSP with the propagation channel (weighted generalization)."""
    print("\n== Bonus: weighted SSSP via propagation channel ==")
    g = gen.rmat(scale, edge_factor=8, seed=6, weighted=True)
    for part, tag in [("random", "weighted"), ("bfs", "weighted (P)")]:
        pg = pgraph.partition_graph(g, common.W, part,
                                    build=("prop_out", "raw_out"))
        for name, key in (("channel (basic)", "sssp:basic"),
                          ("channel (prop)", "sssp:prop")):
            res = _run(key, pg, source=0)
            common.emit("SSSP", f"SSSP {name}", tag, res)
