"""One benchmark per paper table (IV, V-top/mid/bottom, VI, VII)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.algorithms import (msf, pagerank, pointer_jumping, scc, sssp, sv,
                              wcc)
from repro.graph import generators as gen, pgraph


def table4_basic_channels(scale: int):
    """Table IV: Pregel-monolithic vs channel-typed basic implementations.

    PR/WCC/PJ use a single message type, so Pregel's global combiner
    applies and bytes match (as in the paper); the heterogeneous-message
    algorithms (S-V, MSF) show the combiner-inapplicability / padded-type
    costs that channels remove.
    """
    print("\n== Table IV: basic channels vs monolithic Pregel ==")
    pg_web = common.partitioned("web", scale, "random",
                                ("scatter_out", "raw_out"))
    for name, variant in [("pregel (mono)", "basic"),
                          ("channel (basic)", "basic")]:
        _, res = pagerank.run(pg_web, iters=10, variant=variant)
        common.emit("IV", f"PR {name}", "web", res)

    pg_soc = common.partitioned("social", scale, "random",
                                ("scatter_out", "prop_out", "raw_out"))
    for name, variant in [("pregel (mono)", "basic"),
                          ("channel (basic)", "basic")]:
        _, res = wcc.run(pg_soc, variant=variant)
        common.emit("IV", f"WCC {name}", "social", res)

    n = 1 << scale
    empty = gen.EdgeList(n, np.zeros((0, 2), np.int64), None, True, "pj")
    pg_pj = pgraph.partition_graph(empty, common.W, "random", build=())
    par = gen.parent_chain(n, seed=3)
    for name, variant in [("pregel (mono)", "basic"),
                          ("channel (basic)", "basic")]:
        _, res = pointer_jumping.run(pg_pj, par, variant=variant)
        common.emit("IV", f"PJ {name}", "chain", res)

    for name, variant in [("pregel (mono)", "monolithic"),
                          ("channel (basic)", "basic")]:
        _, res = sv.run(pg_soc, variant=variant)
        common.emit("IV", f"S-V {name}", "social", res)

    pg_w = common.partitioned("weighted", scale - 1, "random", ("raw_out",))
    for name, variant in [("pregel (mono)", "monolithic"),
                          ("channel (typed)", "channels")]:
        out, res = msf.run(pg_w, variant=variant)
        common.emit("IV", f"MSF {name}", "weighted", res)


def table5_scatter_combine(scale: int):
    """Table V top: PageRank, CombinedMessage vs ScatterCombine channel."""
    print("\n== Table V (top): scatter-combine channel on PageRank ==")
    for ds in ("web", "social_dense"):
        pg = common.partitioned(ds, scale, "random",
                                ("scatter_out", "raw_out"))
        for name, variant in [("channel (basic)", "basic"),
                              ("channel (scatter)", "scatter")]:
            _, res = pagerank.run(pg, iters=10, variant=variant)
            common.emit("V-top", f"PR {name}", ds, res)


def table5_request_respond(scale: int):
    """Table V middle: Pointer-Jumping, DirectMessage vs RequestRespond."""
    print("\n== Table V (mid): request-respond channel on PJ ==")
    n = 1 << scale
    empty = gen.EdgeList(n, np.zeros((0, 2), np.int64), None, True, "pj")
    pg = pgraph.partition_graph(empty, common.W, "random", build=())
    for ds, par in [("tree", gen.random_tree_parents(n, seed=5)),
                    ("chain", gen.parent_chain(n, seed=5))]:
        for name, variant in [("channel (basic)", "basic"),
                              ("channel (reqresp)", "reqresp")]:
            _, res = pointer_jumping.run(pg, par, variant=variant)
            common.emit("V-mid", f"PJ {name}", ds, res)


def table5_propagation(scale: int):
    """Table V bottom: WCC, CombinedMessage vs Propagation channel, on the
    unpartitioned (random) and partitioned (bfs/METIS-like) graph."""
    print("\n== Table V (bottom): propagation channel on WCC ==")
    for ds, part, tag in [("road", "random", "road"),
                          ("road", "bfs", "road (P)"),
                          ("social", "random", "social"),
                          ("social", "bfs", "social (P)")]:
        pg = common.partitioned(ds, scale, part, ("prop_out", "raw_out"))
        for name, variant in [("channel (basic)", "basic"),
                              ("channel (prop)", "prop")]:
            _, res = wcc.run(pg, variant=variant)
            extra = {}
            if variant == "prop":
                info = np.asarray(res.state["info"])
                extra = {"global_rounds": int(info[:, 0].max()),
                         "inner_iters": int(info[:, 1].max())}
            common.emit("V-bot", f"WCC {name}", tag, res, extra)


def table6_sv_composition(scale: int):
    """Table VI: S-V with every combination of the two optimized channels."""
    print("\n== Table VI: S-V channel composition ==")
    for ds in ("social", "social_dense"):
        pg = common.partitioned(ds, scale, "random",
                                ("scatter_out", "prop_out", "raw_out"))
        for name, variant in [("2-channel (basic)", "basic"),
                              ("3-channel (reqresp)", "reqresp"),
                              ("4-channel (scatter)", "scatter"),
                              ("5-channel (both)", "both")]:
            _, res = sv.run(pg, variant=variant)
            common.emit("VI", f"S-V {name}", ds, res)


def table7_minlabel_scc(scale: int):
    """Table VII: Min-Label SCC with/without the propagation channel."""
    print("\n== Table VII: Min-Label SCC + propagation channel ==")
    for part, tag in [("random", "web"), ("bfs", "web (P)")]:
        pg = common.partitioned(
            "web", scale, part,
            ("scatter_out", "scatter_in", "prop_out", "prop_in",
             "raw_out", "raw_in"))
        for name, variant in [("channel (basic)", "basic"),
                              ("channel (prop)", "prop")]:
            _, res = scc.run(pg, variant=variant)
            common.emit("VII", f"SCC {name}", tag, res)


def bonus_sssp(scale: int):
    """SSSP with the propagation channel (weighted generalization)."""
    print("\n== Bonus: weighted SSSP via propagation channel ==")
    g = gen.rmat(scale, edge_factor=8, seed=6, weighted=True)
    for part, tag in [("random", "weighted"), ("bfs", "weighted (P)")]:
        pg = pgraph.partition_graph(g, common.W, part,
                                    build=("prop_out", "raw_out"))
        for name, variant in [("channel (basic)", "basic"),
                              ("channel (prop)", "prop")]:
            _, res = sssp.run(pg, 0, variant=variant)
            common.emit("SSSP", f"SSSP {name}", tag, res)
