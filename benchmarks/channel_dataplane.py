"""Data-plane microbenchmark: sort-route vs bucket-route, and the
reference vs Pallas segment-combine.

    PYTHONPATH=src python -m benchmarks.channel_dataplane \
        [--scales 10 11 12 13 14 | --scale 10] [--out f]

The paper's thesis is that channel choice governs communication cost;
beneath every *dynamic* channel (DirectMessage / CombinedMessage /
RequestRespond) sits one routed exchange, so its constant factor
multiplies into every superstep of every unoptimized program. This
benchmark times exactly that primitive on the social dataset stand-in:

  - ``route``: one full routed exchange (slot computation + pack + tiled
    all_to_all, ids + one f32 payload) under both implementations —
    ``sort`` (the legacy stable-argsort baseline) and ``bucket`` (the
    one-pass counting data plane, jnp reference path on CPU). Both
    produce bit-identical ``Routed`` results (pinned by
    tests/test_dataplane.py), so this is a pure constant-factor race.
  - ``combine``: the scatter-combine hot loop (sorted-segment reduction
    over one worker's edge array) via the jnp reference vs the Pallas
    kernel with the plan's autotuned block sizes. On CPU the kernel runs
    in interpret mode — a correctness vehicle, recorded for the record,
    not a race it can win; on TPU it is the default path.

Results go to ``BENCH_channel_dataplane.json``; the ``headline`` block
records the bucket-vs-sort speedup at the largest benched scale (the
acceptance bar is >= 1.5x on the host backend).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import routing
from repro.core.channel import ChannelContext
from repro.kernels import ops as kops
from repro.kernels import ref as kref

AXIS = "w"
W = common.W


def _time(fn, *args, repeats=5):
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def bench_route(scale: int, repeats: int):
    """One routed exchange over the raw edge lists of the social graph."""
    pg = common.partitioned("social", scale, "random", ("raw_out",))
    raw = pg.raw_out
    m = raw.e_cap
    payload = {"v": jnp.ones((W, m), jnp.float32)}
    cap = m  # ample capacity: the race is the permutation, not overflow

    def exchange(impl):
        def shard(dst, valid, pay):
            ctx = ChannelContext(AXIS, W, pg.n_loc)
            routed = routing.route(ctx, dst, valid, pay, cap, impl=impl)
            return routed.ids, routed.payload, routed.sent_count

        return jax.jit(jax.vmap(shard, axis_name=AXIS))

    row = {"m_per_worker": int(m)}
    for impl in ("sort", "bucket"):
        t = _time(exchange(impl), raw.dst_global, raw.mask, payload,
                  repeats=repeats)
        row[f"{impl}_s"] = round(t, 6)
        print(f"  scale {scale:2d} route/{impl:7s} M={m:6d} {t*1e3:9.3f} ms")
    row["speedup"] = round(row["sort_s"] / row["bucket_s"], 3)
    print(f"  scale {scale:2d} route speedup (sort/bucket) "
          f"{row['speedup']:.2f}x")
    return row


def bench_combine(scale: int, repeats: int):
    """The sorted-segment combine on one worker's edge array: reference
    vs the Pallas kernel under the plan's autotuned block sizes."""
    pg = common.partitioned("social", scale, "random", ("scatter_out",))
    plan = pg.scatter_out
    seg = plan.edge_seg[0]
    rng = np.random.default_rng(scale)
    vals = jnp.asarray(rng.normal(size=(plan.e_cap, 1)).astype(np.float32))

    ref_fn = jax.jit(lambda v, s: kref.segment_combine_ref(
        v, s, plan.u_cap, "sum"))
    chunk_plan = (plan.chunk_start[0], plan.chunk_count[0], plan.max_chunks)
    kern_fn = jax.jit(lambda v, s: kops.segment_combine(
        v, s, plan.u_cap, "sum", use_kernel=True, assume_sorted=True,
        block_rows=plan.block_rows, block_edges=plan.block_edges,
        chunk_plan=chunk_plan))

    t_ref = _time(ref_fn, vals, seg, repeats=repeats)
    t_kern = _time(kern_fn, vals, seg, repeats=repeats)
    np.testing.assert_allclose(np.asarray(kern_fn(vals, seg)),
                               np.asarray(ref_fn(vals, seg)),
                               rtol=1e-4, atol=1e-4)
    print(f"  scale {scale:2d} combine ref {t_ref*1e3:9.3f} ms   kernel"
          f"({'interpret' if kops.resolve_interpret() else 'tpu'}) "
          f"{t_kern*1e3:9.3f} ms")
    return {
        "edges": int(plan.e_cap),
        "segments": int(plan.u_cap),
        "block_rows": int(plan.block_rows),
        "block_edges": int(plan.block_edges),
        "ref_s": round(t_ref, 6),
        "kernel_s": round(t_kern, 6),
        "kernel_interpret": kops.resolve_interpret(),
    }


def run(scales, repeats: int = 5):
    out = {
        "workers": W,
        "dataset": "social",
        "scales": list(scales),
        "use_kernel_default": kops.resolve_use_kernel(),
        "route_impl_default": routing.resolve_impl(),
        "route": {},
        "combine": {},
        "headline": {},
    }
    for scale in scales:
        out["route"][str(scale)] = bench_route(scale, repeats)
        out["combine"][str(scale)] = bench_combine(scale, repeats)
    largest = str(max(scales))
    out["headline"] = {
        "largest_scale": int(largest),
        "route_speedup": out["route"][largest]["speedup"],
        "target": 1.5,
    }
    print(f"== headline: bucket-route {out['headline']['route_speedup']}x "
          f"faster than sort-route at scale {largest} ==")
    return out


def run_and_write(scales, repeats: int = 5,
                  out_path: str = "BENCH_channel_dataplane.json"):
    print(f"== Channel data plane (social, scales {list(scales)}) ==")
    out = run(scales, repeats)
    out["provenance"] = common.provenance()
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scales", type=int, nargs="+",
                    default=[10, 11, 12, 13, 14])
    ap.add_argument("--scale", type=int, default=None,
                    help="single-scale shorthand (tier-1 smoke)")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--out", default="BENCH_channel_dataplane.json")
    args = ap.parse_args()
    scales = [args.scale] if args.scale is not None else args.scales
    run_and_write(scales, args.repeats, args.out)


if __name__ == "__main__":
    main()
