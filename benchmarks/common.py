"""Shared benchmark infrastructure: datasets, timing, CSV output.

Runtime metric: the first superstep includes jit compilation, so the
reported `runtime` replaces step 0's wall time with the median step time
(raw wall time is also reported). Message bytes are exact (counted by the
channels, remote-only, like the paper's tables).
"""
from __future__ import annotations

import functools
import statistics
import sys

import numpy as np

from repro.graph import generators as gen
from repro.graph import pgraph

W = 8  # logical workers, as in the paper's 8-node cluster


@functools.lru_cache(maxsize=None)
def dataset(name: str, scale: int):
    """Paper-table dataset stand-ins, CPU-sized by `scale`."""
    if name == "web":          # directed power-law (Wikipedia/WebUK)
        return gen.rmat(scale, edge_factor=12, seed=1, directed=True)
    if name == "social":       # undirected power-law (Facebook/Twitter)
        return gen.rmat(scale, edge_factor=8, seed=2).symmetrized()
    if name == "social_dense":  # denser (Twitter-like, avg deg ~48)
        return gen.rmat(scale, edge_factor=24, seed=3).symmetrized()
    if name == "road":          # large-diameter grid (USA-road-like)
        side = int(2 ** (scale / 2))
        return gen.grid2d(side)
    if name == "weighted":      # weighted power-law (RMAT24-like)
        return gen.rmat(scale, edge_factor=8, seed=4,
                        weighted=True).symmetrized()
    raise ValueError(name)


@functools.lru_cache(maxsize=None)
def partitioned(name: str, scale: int, partitioner: str, build: tuple):
    return pgraph.partition_graph(dataset(name, scale), W, partitioner,
                                  build=build)


def provenance() -> dict:
    """The execution-environment stamp every ``BENCH_*.json`` carries:
    where and when the numbers were measured (device kind/count, jax and
    jaxlib versions, UTC timestamp). ``benchmarks.check_schema`` requires
    it — an artifact without provenance can't be compared across PRs."""
    import datetime
    import platform

    import jax
    import jaxlib

    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind,
        "device_count": len(devs),
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib.__version__,
        "python_version": platform.python_version(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }


def adjusted_runtime(res) -> float:
    """Wall time with step-0 compile overhead replaced by the median."""
    ts = res.step_times_s
    if len(ts) <= 1:
        return res.wall_time_s
    med = statistics.median(ts[1:])
    return sum(ts[1:]) + med


ROWS = []


def emit(table: str, program: str, ds: str, res, extra=None):
    runtime = adjusted_runtime(res)
    row = {
        "table": table,
        "program": program,
        "dataset": ds,
        "runtime_s": round(runtime, 4),
        "wall_s": round(res.wall_time_s, 4),
        "message_MB": round(res.total_bytes / 1e6, 4),
        "messages": res.total_msgs,
        "supersteps": res.steps,
    }
    if extra:
        row.update(extra)
    ROWS.append(row)
    print(f"  {program:28s} {ds:14s} runtime {runtime:8.3f}s "
          f"msgs {res.total_bytes/1e6:9.3f} MB  steps {res.steps}")
    return row


def print_csv(file=None):
    f = file or sys.stdout
    cols = ["table", "program", "dataset", "runtime_s", "message_MB",
            "messages", "supersteps"]
    print(",".join(cols), file=f)
    for r in ROWS:
        print(",".join(str(r.get(c, "")) for c in cols), file=f)
