"""Paper-style composition tables: unoptimized vs composed, host vs fused.

    PYTHONPATH=src python -m benchmarks.paper_tables [--scale N] [--out f]

Reproduces the shape of the paper's evaluation tables (§V, Tables IV-VII)
with the composition layer as the subject: for each algorithm, the
*unoptimized* (standard-channel / Pregel-style) program against the
*composed* (optimized-channel-stack) program, under both the ``host``
and ``fused`` execution modes. Rows record supersteps (global rounds),
remote messages, remote bytes, and wall time; the S-V pair is the
paper's headline §V case study — the composed program must win on BOTH
global rounds and traffic bytes, and the emitted JSON
(``BENCH_paper_tables.json``) records that check under ``"headline"``.

Wall times on CPU-sized graphs are dominated by per-superstep dispatch,
which is what the fused column shows; traffic and round counts are exact
and scale-invariant (the channels count logical remote bytes, as the
paper's tables do).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks import common
from repro.algorithms import msf, pagerank, pointer_jumping, sv, wcc
from repro.graph import generators as gen, pgraph

MODES = ("host", "fused")


def _row(algorithm, dataset, mode, program, variant, res, **extra):
    row = {
        "algorithm": algorithm,
        "dataset": dataset,
        "mode": mode,
        "program": program,
        "variant": variant,
        "supersteps": res.steps,
        "messages": res.total_msgs,
        "bytes": res.total_bytes,
        "wall_time_s": round(res.wall_time_s, 4),
        "runtime_s": round(common.adjusted_runtime(res), 4),
        "dispatches": res.dispatches,
    }
    row.update(extra)
    print(f"  {algorithm:4s} {program:12s} [{mode:5s}] "
          f"rounds {res.steps:4d}  msgs {res.total_msgs:9d}  "
          f"bytes {res.total_bytes:11d}  wall {res.wall_time_s:7.3f}s")
    return row


def run(scale: int):
    rows = []

    # --- S-V: the headline composition (paper §V / Table VI) -------------
    pg_soc = common.partitioned("social", scale, "random",
                                ("scatter_out", "prop_out", "raw_out"))
    sv_stats = {}
    for mode in MODES:
        for program, variant in (("unoptimized", "basic"),
                                 ("composed", "composed")):
            _, res = sv.run(pg_soc, variant=variant, mode=mode)
            extra = {}
            if variant == "composed":
                extra["bytes_by_component"] = {
                    k: res.bytes_under(f"sv/{k}")
                    for k in ("pointer", "neighbor_min", "merge", "jump")
                }
            rows.append(_row("S-V", "social", mode, program, variant, res,
                             **extra))
            sv_stats[(mode, program)] = res

    # --- WCC: density switch vs plain push --------------------------------
    for mode in MODES:
        for program, variant in (("unoptimized", "basic"),
                                 ("composed", "switch")):
            _, res = wcc.run(pg_soc, variant=variant, mode=mode)
            rows.append(_row("WCC", "social", mode, program, variant, res))

    # --- PageRank: scatter-combine vs combined message --------------------
    pg_web = common.partitioned("web", scale, "random",
                                ("scatter_out", "raw_out"))
    for mode in MODES:
        for program, variant in (("unoptimized", "basic"),
                                 ("composed", "scatter")):
            _, res = pagerank.run(pg_web, iters=10, variant=variant,
                                  mode=mode)
            rows.append(_row("PR", "web", mode, program, variant, res))

    # --- Pointer jumping: request-respond vs 2-phase direct ---------------
    n = 1 << scale
    empty = gen.EdgeList(n, np.zeros((0, 2), np.int64), None, True, "pj")
    pg_pj = pgraph.partition_graph(empty, common.W, "random", build=())
    par = gen.random_tree_parents(n, seed=5)
    for mode in MODES:
        for program, variant in (("unoptimized", "basic"),
                                 ("composed", "reqresp")):
            _, res = pointer_jumping.run(pg_pj, par, variant=variant,
                                         mode=mode)
            rows.append(_row("PJ", "tree", mode, program, variant, res))

    # --- MSF: the typed-channel stack vs monolithic Pregel ----------------
    pg_w = common.partitioned("weighted", max(scale - 2, 6), "random",
                              ("raw_out",))
    for mode in MODES:
        for program, variant in (("unoptimized", "monolithic"),
                                 ("composed", "channels")):
            _, res = msf.run(pg_w, variant=variant, mode=mode)
            rows.append(_row("MSF", "weighted", mode, program, variant, res))

    # --- headline check: composed S-V beats unoptimized S-V ---------------
    basic = sv_stats[("fused", "unoptimized")]
    comp = sv_stats[("fused", "composed")]
    headline = {
        "algorithm": "S-V",
        "unoptimized_supersteps": basic.steps,
        "composed_supersteps": comp.steps,
        "unoptimized_bytes": basic.total_bytes,
        "composed_bytes": comp.total_bytes,
        "round_reduction": round(basic.steps / max(comp.steps, 1), 3),
        "traffic_reduction": round(
            basic.total_bytes / max(comp.total_bytes, 1), 3),
        "composed_beats_unoptimized_rounds": comp.steps < basic.steps,
        "composed_beats_unoptimized_bytes":
            comp.total_bytes < basic.total_bytes,
    }
    print(f"\nheadline: composed S-V {headline['round_reduction']}x fewer "
          f"global rounds, {headline['traffic_reduction']}x less traffic "
          f"than unoptimized")
    return rows, headline


def run_and_write(scale: int, out_path: str = "BENCH_paper_tables.json"):
    print(f"== Paper composition tables (scale {scale}, W={common.W}) ==")
    rows, headline = run(scale)
    out = {"scale": scale, "workers": common.W, "rows": rows,
           "headline": headline}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    if not (headline["composed_beats_unoptimized_rounds"]
            and headline["composed_beats_unoptimized_bytes"]):
        raise SystemExit(
            "headline regression: composed S-V did not beat the "
            "unoptimized S-V on rounds and bytes"
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--out", default="BENCH_paper_tables.json")
    args = ap.parse_args()
    run_and_write(args.scale, args.out)


if __name__ == "__main__":
    main()
