"""Paper-style composition tables: unoptimized vs composed, host vs fused.

    PYTHONPATH=src python -m benchmarks.paper_tables [--scale N] [--out f]

Reproduces the shape of the paper's evaluation tables (§V, Tables IV-VII)
with the composition layer as the subject: for each algorithm, the
*unoptimized* (standard-channel / Pregel-style) program against the
*composed* (optimized-channel-stack) program, under both the ``host``
and ``fused`` execution modes. Rows record supersteps (global rounds),
remote messages, remote bytes, and wall time; the S-V pair is the
paper's headline §V case study — the composed program must win on BOTH
global rounds and traffic bytes, and the emitted JSON
(``BENCH_paper_tables.json``) records that check under ``"headline"``.

The whole table is driven by the program registry
(``repro.algorithms.REGISTRY``) through one compile-once
``repro.pregel.engine.Engine`` per execution mode: each (program, shape)
is compiled at most once per mode, a warm re-run of the composed S-V
demonstrates the session cache (``"engine"`` in the JSON records the
compile/cache-hit counters), and there is no per-algorithm glue — a row
is just (label, registry key, knobs).

Wall times on CPU-sized graphs are dominated by per-superstep dispatch,
which is what the fused column shows; traffic and round counts are exact
and scale-invariant (the channels count logical remote bytes, as the
paper's tables do).
"""
from __future__ import annotations

import argparse
import json

from benchmarks import common
from repro.algorithms import REGISTRY
from repro.graph import pgraph
from repro.pregel.engine import Engine

MODES = ("host", "fused")

# (algorithm row label, paper dataset, [(program label, registry key,
# factory knobs)]). The composed S-V also reports per-component bytes.
CASES = (
    ("S-V", "social",
     (("unoptimized", "sv:basic", {}), ("composed", "sv:composed", {}))),
    ("WCC", "social",
     (("unoptimized", "wcc:basic", {}), ("composed", "wcc:switch", {}))),
    ("PR", "web",
     (("unoptimized", "pagerank:basic", {"iters": 10}),
      ("composed", "pagerank:scatter", {"iters": 10}))),
    ("PJ", "tree",
     (("unoptimized", "pj:basic", {}), ("composed", "pj:reqresp", {}))),
    ("MSF", "weighted",
     (("unoptimized", "msf:monolithic", {}),
      ("composed", "msf:channels", {}))),
)


def _instance(spec, dataset: str, scale: int):
    """Problem instance for a row: the paper stand-in datasets for the
    graph algorithms, the spec's own generator for the forest (PJ)."""
    if dataset == "tree":
        graph = spec.make_graph(scale, 0)
        pg = pgraph.partition_graph(graph, common.W, "random",
                                    build=spec.build)
    else:
        s = max(scale - 2, 6) if spec.algorithm == "msf" else scale
        graph = common.dataset(dataset, s)
        pg = common.partitioned(dataset, s, "random", spec.build)
    return graph, pg, spec.inputs(graph, 0)


def _row(algorithm, dataset, mode, program, res, **extra):
    row = {
        "algorithm": algorithm,
        "dataset": dataset,
        "mode": mode,
        "program": program,
        "variant": res.program,
        "supersteps": res.steps,
        "messages": res.total_msgs,
        "bytes": res.total_bytes,
        "wall_time_s": round(res.wall_time_s, 4),
        "runtime_s": round(common.adjusted_runtime(res), 4),
        "dispatches": res.dispatches,
        "compile_time_s": round(res.compile_time_s, 4),
        "cache_hit": res.cache_hit,
    }
    row.update(extra)
    print(f"  {algorithm:4s} {program:12s} [{mode:5s}] "
          f"rounds {res.steps:4d}  msgs {res.total_msgs:9d}  "
          f"bytes {res.total_bytes:11d}  wall {res.wall_time_s:7.3f}s")
    return row


def run(scale: int):
    engines = {m: Engine(mode=m) for m in MODES}
    rows = []
    sv_stats = {}
    progs = {}

    pg_by_algorithm = {}
    for algorithm, dataset, programs in CASES:
        # one problem instance per case — shared by every (mode, program)
        graph, pg, inputs = _instance(REGISTRY[programs[0][1]], dataset,
                                      scale)
        pg_by_algorithm[algorithm] = pg
        for mode in MODES:
            for label, key, knobs in programs:
                spec = REGISTRY[key]
                # one program instance per (key, knobs) across both modes
                if key not in progs:
                    progs[key] = spec.factory(**inputs, **knobs)
                res = engines[mode].run(progs[key], pg)
                extra = {}
                if key == "sv:composed":
                    extra["bytes_by_component"] = {
                        k: res.bytes_under(f"sv/{k}")
                        for k in ("pointer", "neighbor_min", "merge", "jump")
                    }
                rows.append(_row(algorithm, dataset, mode, label, res,
                                 **extra))
                if algorithm == "S-V":
                    sv_stats[(mode, label)] = res

    # --- session cache demo: warm re-run of the composed S-V -------------
    warm = engines["fused"].run(progs["sv:composed"], pg_by_algorithm["S-V"])
    assert warm.cache_hit, "same program+shape must reuse the compile"
    engine_stats = {m: engines[m].stats() for m in MODES}
    engine_stats["warm_rerun"] = {
        "program": warm.program,
        "cache_hit": warm.cache_hit,
        "wall_time_s": round(warm.wall_time_s, 4),
        "cold_wall_time_s": sv_stats[("fused", "composed")].wall_time_s,
        "cold_compile_time_s": round(
            sv_stats[("fused", "composed")].compile_time_s, 4),
    }
    print(f"\nengine sessions: {engine_stats}")

    # --- headline check: composed S-V beats unoptimized S-V ---------------
    basic = sv_stats[("fused", "unoptimized")]
    comp = sv_stats[("fused", "composed")]
    headline = {
        "algorithm": "S-V",
        "unoptimized_supersteps": basic.steps,
        "composed_supersteps": comp.steps,
        "unoptimized_bytes": basic.total_bytes,
        "composed_bytes": comp.total_bytes,
        "round_reduction": round(basic.steps / max(comp.steps, 1), 3),
        "traffic_reduction": round(
            basic.total_bytes / max(comp.total_bytes, 1), 3),
        "composed_beats_unoptimized_rounds": comp.steps < basic.steps,
        "composed_beats_unoptimized_bytes":
            comp.total_bytes < basic.total_bytes,
    }
    print(f"headline: composed S-V {headline['round_reduction']}x fewer "
          f"global rounds, {headline['traffic_reduction']}x less traffic "
          f"than unoptimized")
    return rows, headline, engine_stats


def run_and_write(scale: int, out_path: str = "BENCH_paper_tables.json"):
    print(f"== Paper composition tables (scale {scale}, W={common.W}) ==")
    rows, headline, engine_stats = run(scale)
    out = {"scale": scale, "workers": common.W, "rows": rows,
           "headline": headline, "engine": engine_stats,
           "provenance": common.provenance()}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    if not (headline["composed_beats_unoptimized_rounds"]
            and headline["composed_beats_unoptimized_bytes"]):
        raise SystemExit(
            "headline regression: composed S-V did not beat the "
            "unoptimized S-V on rounds and bytes"
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--out", default="BENCH_paper_tables.json")
    args = ap.parse_args()
    run_and_write(args.scale, args.out)


if __name__ == "__main__":
    main()
