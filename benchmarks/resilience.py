"""Resilience benchmark: the cost and fidelity of the recovery paths.

    PYTHONPATH=src python -m benchmarks.resilience [--scale 10]
        [--out BENCH_resilience.json]

Three drills, all verified bit-identical before anything is reported:

  escalation  run a program with every channel capacity halved under
              ``Engine(on_overflow="escalate")`` and measure what the
              re-bucket-and-replay recovery costs next to the untouched
              run (retries taken, recovered wall time / baseline wall
              time) — plus the memoized second run, which must take zero
              retries because the engine learned the right caps.
  checkpoint  a chunked run snapshotted every K supersteps vs the same
              run unsnapshotted (checkpoint overhead), then a resume
              from the newest mid-run snapshot (must replay the
              uninterrupted run byte for byte).
  quarantine  a serving session with deterministic fault injections on a
              subset of qids: the failed queries are quarantined, every
              healthy query must still match its solo run bit for bit,
              and the session reports the failures instead of dying.

The headline is the conjunction: all three drills recovered AND stayed
bit-identical. ``scripts/tier1.sh`` runs a small smoke of this benchmark
and schema-checks the artifact (``benchmarks.check_schema``).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.algorithms import REGISTRY
from repro.graph import pgraph
from repro.pregel import checkpoint as ckpt_io
from repro.pregel.engine import Engine
from repro.pregel.serve import FaultSpec

W = 8
ESCALATE_KEY = "wcc:basic"
SERVE_KEY = "reach:basic"


def _same(a, b) -> bool:
    import jax

    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def _problem(key: str, scale: int, seed: int = 0):
    spec = REGISTRY[key]
    graph = spec.make_graph(scale, seed)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    return graph, pg, spec.factory(**spec.inputs(graph, seed))


def bench_escalation(scale: int, seed: int = 0) -> dict:
    _, pg, prog = _problem(ESCALATE_KEY, scale, seed)
    base_eng = Engine()
    ref = base_eng.run(prog, pg)         # compile
    t0 = time.perf_counter()
    ref = base_eng.run(prog, pg)         # warm baseline
    t_base = time.perf_counter() - t0

    eng = Engine(cap_scales={"*": 0.5}, on_overflow="escalate")
    t0 = time.perf_counter()
    res = eng.run(prog, pg)              # cold: pays retries + compiles
    t_recover = time.perf_counter() - t0
    t0 = time.perf_counter()
    res2 = eng.run(prog, pg)             # memoized: right-sized start
    t_memo = time.perf_counter() - t0

    retries = len(res.recovery or [])
    return {
        "program": ESCALATE_KEY,
        "cap_scale": 0.5,
        "retries": retries,
        "recovery": [dict(ev, channels=list(ev["channels"]))
                     for ev in (res.recovery or [])],
        "retries_memoized": len(res2.recovery or []),
        "wall_baseline_s": t_base,
        "wall_recovered_s": t_recover,
        "wall_memoized_s": t_memo,
        "bit_identical": bool(
            _same(res.output, ref.output) and res.steps == ref.steps
            and res.bytes_by_channel == ref.bytes_by_channel),
        "memoized_bit_identical": bool(_same(res2.output, ref.output)),
    }


def bench_checkpoint(scale: int, ckpt_dir: str, every: int = 2,
                     seed: int = 0) -> dict:
    _, pg, prog = _problem(ESCALATE_KEY, scale, seed)
    eng = Engine(mode="chunked", chunk_size=2)
    plain = eng.run(prog, pg)            # compile + baseline
    t0 = time.perf_counter()
    plain = eng.run(prog, pg)
    t_plain = time.perf_counter() - t0

    t0 = time.perf_counter()
    full = eng.run(prog, pg, checkpoint_every=every,
                   checkpoint_dir=ckpt_dir)
    t_ckpt = time.perf_counter() - t0

    newest = ckpt_io.latest(ckpt_dir)
    ck = ckpt_io.load(newest) if newest else None
    resumed = (Engine(mode="chunked", chunk_size=2).run(
        prog, pg, resume=ck) if ck else None)
    return {
        "program": ESCALATE_KEY,
        "checkpoint_every": every,
        "steps": int(full.steps),
        "checkpoints_written": 0 if ck is None else int(ck.step // every),
        "wall_plain_s": t_plain,
        "wall_checkpointed_s": t_ckpt,
        "overhead_frac": (t_ckpt - t_plain) / t_plain if t_plain else 0.0,
        "resumed_from": 0 if resumed is None else int(resumed.resumed_from),
        "resume_bit_identical": bool(
            resumed is not None
            and _same(resumed.output, full.output)
            and resumed.steps == full.steps
            and resumed.bytes_by_channel == full.bytes_by_channel
            and resumed.msgs_by_channel == full.msgs_by_channel),
    }


def bench_quarantine(scale: int, q: int = 12, lanes: int = 4,
                     chunk: int = 2, seed: int = 0) -> dict:
    graph, pg, prog = _problem(SERVE_KEY, scale, seed)
    spec = REGISTRY[SERVE_KEY]
    queries = [int(s) for s in spec.queries(graph, seed, q)]
    faults = [FaultSpec(qid=1, at_step=1, kind="overflow"),
              FaultSpec(qid=q - 2, at_step=0, kind="overflow"),
              FaultSpec(qid=q // 2, at_step=2, kind="exhaust")]
    eng = Engine(mode="chunked", chunk_size=chunk)
    t0 = time.perf_counter()
    res = eng.serve(prog, pg, queries, num_lanes=lanes, faults=faults)
    wall = time.perf_counter() - t0

    faulted = {f.qid for f in faults}
    healthy_identical = True
    for rec in res.records:
        if rec.qid in faulted:
            continue
        solo = eng.run_batch(prog, pg, [rec.query])
        healthy_identical &= (
            _same(rec.output, solo.outputs[0])
            and rec.steps == int(solo.query_steps[0])
            and rec.bytes_by_channel == solo.query_bytes(0))
    return {
        "program": SERVE_KEY,
        "q": q,
        "lanes": lanes,
        "chunk_size": chunk,
        "faults": [{"qid": f.qid, "at_step": f.at_step, "kind": f.kind}
                   for f in faults],
        "failed_qids": list(res.failed_qids),
        "statuses": {str(r.qid): r.status for r in res.records},
        "served": int(res.num_queries),
        "wall_s": wall,
        "straggler_dispatches": list(res.straggler_dispatches),
        "dispatch_median_s": float(res.dispatch_median_s),
        "quarantine_isolated": bool(
            healthy_identical
            and res.num_queries == q
            and set(res.failed_qids)
            == {f.qid for f in faults if f.kind == "overflow"}),
    }


def run(scale: int = 10, ckpt_dir: str = None, seed: int = 0) -> dict:
    import tempfile

    if ckpt_dir is None:
        ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    print("== escalation drill ==")
    esc = bench_escalation(scale, seed)
    print(f"  {esc['program']}: {esc['retries']} retries, recovered "
          f"{esc['wall_recovered_s']:.2f}s vs baseline "
          f"{esc['wall_baseline_s']:.2f}s, memoized retries "
          f"{esc['retries_memoized']} "
          f"[bit-identical: {esc['bit_identical']}]")
    print("== checkpoint drill ==")
    ck = bench_checkpoint(scale, ckpt_dir, seed=seed)
    print(f"  {ck['program']}: {ck['steps']} steps, overhead "
          f"{ck['overhead_frac'] * 100:.1f}%, resumed from superstep "
          f"{ck['resumed_from']} [bit-identical: "
          f"{ck['resume_bit_identical']}]")
    print("== quarantine drill ==")
    qa = bench_quarantine(scale, seed=seed)
    print(f"  {qa['program']}: served {qa['served']}, failed qids "
          f"{qa['failed_qids']} [isolated: {qa['quarantine_isolated']}]")

    ok = (esc["bit_identical"] and esc["memoized_bit_identical"]
          and esc["retries_memoized"] == 0
          and ck["resume_bit_identical"] and qa["quarantine_isolated"])
    out = {
        "scale": scale,
        "workers": W,
        "seed": seed,
        "escalation": esc,
        "checkpoint": ck,
        "quarantine": qa,
        "headline": {
            "escalate_bit_identical": esc["bit_identical"],
            "resume_bit_identical": ck["resume_bit_identical"],
            "quarantine_isolated": qa["quarantine_isolated"],
            "escalation_retries": esc["retries"],
            "checkpoint_overhead_frac": ck["overhead_frac"],
            "target": "all recovery paths bit-identical",
            "meets_target": bool(ok),
        },
    }
    print(f"  headline: all drills bit-identical = {ok}")
    return out


def run_and_write(scale: int = 10, seed: int = 0,
                  out_path: str = "BENCH_resilience.json"):
    print(f"== Resilience (scale {scale}, W={W}) ==")
    out = run(scale, seed=seed)
    from benchmarks import common
    out["provenance"] = common.provenance()
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_resilience.json")
    args = ap.parse_args()
    run_and_write(args.scale, args.seed, args.out)


if __name__ == "__main__":
    main()
