"""BENCH_*.json schema check: the artifact keys are a cross-PR contract.

    PYTHONPATH=src python -m benchmarks.check_schema [extra.json ...]

Downstream tooling (and the PR-over-PR comparisons in CHANGES.md) reads
the committed ``BENCH_*.json`` artifacts by key; a benchmark refactor
that silently renames or drops keys breaks those readers long after the
PR lands. This checker pins the required top-level key set per artifact
— run by ``scripts/tier1.sh`` (full mode) against every committed
``BENCH_*.json`` plus any extra paths passed on the command line (e.g.
a fresh smoke artifact). Extra keys are allowed (schemas may grow);
missing keys fail.
"""
from __future__ import annotations

import json
import pathlib
import sys

# Required top-level keys per artifact basename. Append when a benchmark
# grows a field; never remove without bumping every reader. Every
# artifact additionally carries "provenance" (added below): numbers
# without a device/version stamp can't be compared across PRs.
EXPECTED = {
    "BENCH_paper_tables.json": {
        "scale", "workers", "rows", "headline", "engine",
    },
    "BENCH_superstep_fusion.json": {
        "n", "workers", "variant", "repeats", "chunk_size", "modes",
        "overhead_reduction_fused", "overhead_reduction_chunked",
    },
    "BENCH_channel_dataplane.json": {
        "workers", "dataset", "scales", "use_kernel_default",
        "route_impl_default", "route", "combine", "headline",
    },
    "BENCH_query_throughput.json": {
        "scale", "workers", "q", "repeats", "mode", "programs", "headline",
    },
    "BENCH_routed_batching.json": {
        "scale", "workers", "q", "repeats", "mode", "programs", "headline",
    },
    "BENCH_serving.json": {
        "scale", "workers", "q", "lanes", "chunk_size", "rate", "seed",
        "mode", "programs", "headline",
    },
    "BENCH_planner.json": {
        "workers", "dataset", "scales", "repeats", "programs", "configs",
        "rows", "headline",
    },
    "BENCH_resilience.json": {
        "scale", "workers", "seed", "escalation", "checkpoint",
        "quarantine", "headline",
    },
    "BENCH_weak_scaling.json": {
        "scale", "devices", "repeats", "seed", "program", "dataset",
        "rows", "headline",
    },
}
for _keys in EXPECTED.values():
    _keys.add("provenance")

# The provenance stamp itself (written by benchmarks.common.provenance).
PROVENANCE = {"backend", "device_kind", "device_count", "jax_version",
              "jaxlib_version", "python_version", "timestamp_utc"}

# Required keys inside nested blocks (artifact basename -> path -> keys).
NESTED = {
    "BENCH_channel_dataplane.json": {
        "headline": {"largest_scale", "route_speedup", "target"},
    },
    "BENCH_query_throughput.json": {
        "headline": {"program", "scale", "q", "speedup", "target",
                     "queries_per_s_batched", "queries_per_s_serial",
                     "meets_target"},
    },
    "BENCH_routed_batching.json": {
        "headline": {"program", "scale", "q", "speedup_union",
                     "speedup_lane", "union_vs_lane", "target",
                     "queries_per_s_union", "queries_per_s_serial",
                     "meets_target"},
    },
    "BENCH_serving.json": {
        "headline": {"program", "scale", "q", "lanes", "speedup",
                     "queries_per_s_serve", "queries_per_s_batch",
                     "p50_latency_steps", "p99_latency_steps",
                     "p50_latency_s", "p99_latency_s", "target",
                     "meets_target"},
    },
    "BENCH_planner.json": {
        "headline": {"scale", "geomean_vs_best", "geomean_vs_worst",
                     "target_vs_best", "target_vs_worst", "meets_target",
                     "bit_identical"},
    },
    "BENCH_resilience.json": {
        "headline": {"escalate_bit_identical", "resume_bit_identical",
                     "quarantine_isolated", "escalation_retries",
                     "checkpoint_overhead_frac", "target", "meets_target"},
    },
    "BENCH_weak_scaling.json": {
        "headline": {"program", "dataset", "devices_max",
                     "per_device_ratio", "random_ratio",
                     "msg_ratio_random", "target", "meets_target",
                     "bit_identical"},
    },
}
for _name in EXPECTED:
    NESTED.setdefault(_name, {})["provenance"] = PROVENANCE


def check(path: pathlib.Path) -> list:
    spec = EXPECTED.get(path.name)
    if spec is None:
        return [f"{path}: no schema registered for this artifact name"]
    data = json.loads(path.read_text())
    errors = []
    missing = spec - set(data)
    if missing:
        errors.append(f"{path}: missing top-level keys {sorted(missing)}")
    for block, keys in NESTED.get(path.name, {}).items():
        sub = data.get(block, {})
        gone = keys - set(sub)
        if gone:
            errors.append(f"{path}: missing {block!r} keys {sorted(gone)}")
    return errors


def main() -> int:
    paths = [pathlib.Path(p) for p in sys.argv[1:]]
    paths += sorted(pathlib.Path(".").glob("BENCH_*.json"))
    if not paths:
        print("check_schema: no BENCH_*.json artifacts found")
        return 1
    errors = []
    for path in dict.fromkeys(paths):  # dedup, keep order
        errs = check(path)
        errors.extend(errs)
        print(f"  {path}: {'FAILED' if errs else 'ok'}")
    for e in errors:
        print(f"check_schema: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
