"""Planner race: ``Engine(plan="auto")`` vs hand-set configurations.

    PYTHONPATH=src python -m benchmarks.planner \
        [--scales 10 11 12 | --scale 10] [--repeats 3] [--out f]

The planner's promise is twofold: it never loses to a careful hand-set
configuration (the knobs a maintainer who read every BENCH artifact
would pick), and it saves a careless one (plausible knobs copied from
the wrong backend — the interpreted Pallas kernel on CPU, the argsort
route baseline). This benchmark races all three over registry programs
at several scales:

  planner   Engine(plan="auto") — the cost-model decision per
            (program, graph) fingerprint
  best      the hand-tuned CPU config: reference combine, bucket route
  worst     the plausible-but-wrong config: kernel combine (interpreted
            on CPU), argsort route

and asserts, before timing anything, that every planned run's output is
bit-identical to its hand-set equivalent (same knobs, explicit) AND to
the best/worst configs — the planner only picks among proven-identical
implementations, so it can never trade correctness for speed.

Headline (largest scale): geomean over programs of t_hand / t_planner.
Targets: >= 1.0x vs best (the planner finds the good config), >= 1.3x
vs worst (it saves the bad one).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.algorithms import REGISTRY
from repro.graph import pgraph
from repro.pregel.engine import Engine

W = 8
TARGET_VS_BEST = 1.0
TARGET_VS_WORST = 1.3
DEFAULT_KEYS = ("wcc:switch", "pagerank:scatter", "sssp:basic")

# Hand-set data-plane configs (mode/chunk left at their defaults — the
# race is about the data-plane knobs the corpus actually measures).
CONFIGS = {
    "best": dict(use_kernel=False, route_impl="bucket"),
    "worst": dict(use_kernel=True, route_impl="sort"),
}


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _bench_program(key: str, scale: int, repeats: int):
    spec = REGISTRY[key]
    graph = spec.make_graph(scale, 0)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    prog = spec.factory(**spec.inputs(graph, 0))

    planner_eng = Engine(plan="auto")
    res_p = planner_eng.run(prog, pg)
    plan = res_p.plan

    # the planned run must be bit-identical to the hand-set equivalent
    # (every plan knob passed explicitly to a manual engine) ...
    equiv = Engine(mode=plan.mode, chunk_size=plan.chunk_size,
                   use_kernel=plan.use_kernel, route_impl=plan.route_impl,
                   route_batch=plan.route_batch,
                   dense_threshold=plan.dense_threshold)
    np.testing.assert_array_equal(np.asarray(res_p.output),
                                  np.asarray(equiv.run(prog, pg).output))

    # ... and to every raced config (the planner only selects among
    # proven output-identical implementations)
    engines, times = {}, {}
    for name, cfg in CONFIGS.items():
        eng = Engine(**cfg)
        res = eng.run(prog, pg)  # warm + verify
        np.testing.assert_array_equal(np.asarray(res_p.output),
                                      np.asarray(res.output))
        engines[name] = eng

    times["planner"] = min(
        _timed(lambda: planner_eng.run(prog, pg)) for _ in range(repeats))
    for name, eng in engines.items():
        times[name] = min(
            _timed(lambda e=eng: e.run(prog, pg)) for _ in range(repeats))

    row = {
        "program": key,
        "scale": scale,
        "graph_n": graph.n,
        "supersteps": int(res_p.steps),
        "wall_s": {k: round(v, 5) for k, v in times.items()},
        "vs_best": times["best"] / times["planner"],
        "vs_worst": times["worst"] / times["planner"],
        "planner_knobs": plan.knobs(),
        "plan_source": plan.source,
        "bit_identical": True,
    }
    print(f"  {key:20s} scale {scale:2d}  "
          f"planner {times['planner'] * 1e3:8.2f}ms  "
          f"best {times['best'] * 1e3:8.2f}ms ({row['vs_best']:5.2f}x)  "
          f"worst {times['worst'] * 1e3:8.2f}ms ({row['vs_worst']:5.2f}x)"
          f"  [outputs bit-identical]")
    return row


def _geomean(xs):
    return float(np.exp(np.mean(np.log(xs))))


def run(scales, repeats: int = 3, keys=DEFAULT_KEYS):
    out = {"workers": W, "dataset": "registry defaults",
           "scales": list(scales), "repeats": repeats,
           "programs": list(keys),
           "configs": {k: dict(v) for k, v in CONFIGS.items()},
           "rows": []}
    for scale in scales:
        for key in keys:
            out["rows"].append(_bench_program(key, scale, repeats))
    top = max(scales)
    at_top = [r for r in out["rows"] if r["scale"] == top]
    geo_best = _geomean([r["vs_best"] for r in at_top])
    geo_worst = _geomean([r["vs_worst"] for r in at_top])
    out["headline"] = {
        "scale": top,
        "geomean_vs_best": round(geo_best, 3),
        "geomean_vs_worst": round(geo_worst, 3),
        "target_vs_best": TARGET_VS_BEST,
        "target_vs_worst": TARGET_VS_WORST,
        "meets_target": (geo_best >= TARGET_VS_BEST
                         and geo_worst >= TARGET_VS_WORST),
        "bit_identical": all(r["bit_identical"] for r in out["rows"]),
    }
    print(f"  headline: scale {top}  "
          f"geomean vs best {geo_best:.2f}x (target {TARGET_VS_BEST}x)  "
          f"vs worst {geo_worst:.2f}x (target {TARGET_VS_WORST}x)")
    return out


def run_and_write(scales, repeats: int = 3, keys=DEFAULT_KEYS,
                  out_path: str = "BENCH_planner.json"):
    print(f"== Planner race (scales {list(scales)}, W={W}) ==")
    out = run(scales, repeats, keys)
    from benchmarks import common
    out["provenance"] = common.provenance()
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scales", type=int, nargs="+", default=None)
    ap.add_argument("--scale", type=int, default=None,
                    help="single-scale shorthand (the CI smoke)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--keys", default=None,
                    help="comma list of programs to race")
    ap.add_argument("--out", default="BENCH_planner.json")
    args = ap.parse_args()
    scales = args.scales or ([args.scale] if args.scale else [10, 11, 12])
    keys = tuple(args.keys.split(",")) if args.keys else DEFAULT_KEYS
    run_and_write(scales, repeats=args.repeats, keys=keys,
                  out_path=args.out)


if __name__ == "__main__":
    main()
