"""Dispatch-overhead benchmark: host-driven loop vs fused on-device loop.

    PYTHONPATH=src python -m benchmarks.superstep_fusion [--scale 14] [--out f]

Pointer jumping is the adversarial case for a host-driven runtime: many
cheap supersteps, so per-superstep *host overhead* — the dispatch enqueue,
the blocking halt/overflow readback and the per-step stat transfers —
rather than channel traffic governs the loop rate. The runtime instruments
exactly that cost (``RunResult.host_overhead_s``: host time spent driving
the loop, device waits excluded). The fused ``lax.while_loop`` mode pays
it once per *run* and the chunked ``lax.scan`` mode once per *chunk*,
instead of once per superstep.

The benchmark runs the same 2^scale-vertex pointer-jumping program under
all three modes and reports, per mode: per-superstep wall time and
per-superstep host overhead, plus the host-vs-fused overhead-reduction
factor. Results go to ``BENCH_superstep_fusion.json``.
"""
from __future__ import annotations

import argparse
import json
import statistics

import numpy as np

from repro.algorithms import pointer_jumping
from repro.graph import generators as gen, pgraph

W = 8


def _overhead_per_step(res) -> float:
    # host mode: step 0's enqueue is excluded by the runtime (compile),
    # so normalize by the steps that were actually instrumented
    denom = max(res.steps - 1, 1) if res.mode == "host" else res.steps
    return res.host_overhead_s / denom


def run(scale: int = 14, repeats: int = 5, chunk_size: int = 8):
    n = 2 ** scale
    # a parent chain maximizes supersteps (ceil(log2 depth) jumping rounds)
    par = gen.parent_chain(n, seed=1)
    empty = gen.EdgeList(n, np.zeros((0, 2), np.int64), None, True, "pj")
    pg = pgraph.partition_graph(empty, W, "random", build=())

    out = {"n": n, "workers": W, "variant": "reqresp", "repeats": repeats,
           "chunk_size": chunk_size, "modes": {}}
    for mode in ("host", "fused", "chunked"):
        per_step, ovh, steps = [], [], None
        for _ in range(repeats):
            _, res = pointer_jumping.run(pg, par, variant="reqresp",
                                         mode=mode, chunk_size=chunk_size)
            tail = res.step_times_s[1:] or res.step_times_s
            per_step.append(
                statistics.median(tail) if mode == "host"
                else res.wall_time_s / max(res.steps, 1)
            )
            ovh.append(_overhead_per_step(res))
            steps = res.steps
        out["modes"][mode] = {
            "supersteps": steps,
            "dispatches": res.dispatches,
            "per_superstep_wall_s": min(per_step),
            "host_overhead_per_superstep_s": min(ovh),
            "host_overhead_per_superstep_median_s": statistics.median(ovh),
        }
        print(f"  {mode:8s} steps {steps:3d} dispatches {res.dispatches:3d} "
              f"per-superstep {min(per_step)*1e3:8.3f} ms  "
              f"host-overhead/step {min(ovh)*1e3:7.3f} ms")

    h = out["modes"]["host"]["host_overhead_per_superstep_s"]
    f = out["modes"]["fused"]["host_overhead_per_superstep_s"]
    c = out["modes"]["chunked"]["host_overhead_per_superstep_s"]
    out["overhead_reduction_fused"] = h / f
    out["overhead_reduction_chunked"] = h / c
    print(f"  per-superstep host overhead: host/fused {h / f:7.2f}x  "
          f"host/chunked {h / c:7.2f}x")
    return out


def run_and_write(scale: int = 14, repeats: int = 5, chunk_size: int = 8,
                  out_path: str = "BENCH_superstep_fusion.json"):
    """Run the benchmark and persist its JSON artifact (single writer —
    also what benchmarks/run.py calls for the `fusion` table)."""
    print(f"== Superstep fusion (pointer jumping, n=2^{scale}) ==")
    out = run(scale, repeats, chunk_size)
    from benchmarks import common
    out["provenance"] = common.provenance()
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--chunk-size", type=int, default=8)
    ap.add_argument("--out", default="BENCH_superstep_fusion.json")
    args = ap.parse_args()
    run_and_write(args.scale, args.repeats, args.chunk_size, args.out)


if __name__ == "__main__":
    main()
