"""Routed-channel batching benchmark: the union-frontier route pass vs
the per-lane baseline vs a serial per-query loop.

    PYTHONPATH=src python -m benchmarks.routed_batching [--scale 12]
        [--queries 32] [--out BENCH_routed_batching.json]

``benchmarks/query_throughput.py`` measured the PR-5 moral: batching
paid off only where the channel plan is *static* — the dynamically
routed channels (CombinedMessage dedup + wire packing, RequestRespond)
re-paid their route pass per query lane and landed below 1x. This
benchmark measures the fix: with ``route_batch="union"`` every routed
channel computes the union frontier across the Q lanes each superstep
and runs ONE shared bucket-route pass, with payloads riding as
``(slots, Q)`` lane matrices.

Three executions of the same program through warm ``Engine`` sessions
(never a compile inside a timed region):

  - serial: Q ``run_batch(prog, pg, [s])`` calls — one compiled Q=1
    executable replayed per query;
  - lane:   ``Engine(route_batch="lane")`` — the PR-5 baseline, the
    query vmap batches Q independent route passes;
  - union:  ``Engine(route_batch="union")`` — one shared route pass.

Per-query outputs are asserted bit-identical across all three before
anything is timed. Results (queries/sec per program plus the
``headline`` union-vs-serial speedup, target >= 3x for sssp:basic at
scale 12 / Q=32) go to ``BENCH_routed_batching.json``;
``scripts/tier1.sh`` (full mode) runs a small smoke of this benchmark
and schema-checks the artifact.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.algorithms import REGISTRY
from repro.graph import pgraph
from repro.pregel.engine import Engine

W = 8
HEADLINE_PROGRAM = "sssp:basic"
TARGET = 3.0
# every query-parametric program whose channels are dynamically routed
DEFAULT_KEYS = ("sssp:basic", "reach:basic", "pj:reqresp")


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _bench_program(key: str, scale: int, q: int, repeats: int):
    spec = REGISTRY[key]
    graph = spec.make_graph(scale, 0)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    queries = spec.queries(graph, 0, q)
    q = len(queries)  # queries() clamps to graph.n — rate by actual Q
    prog = spec.factory(**spec.inputs(graph, 0))
    engines = {
        "serial": Engine(mode="fused", route_batch="union"),
        "lane": Engine(mode="fused", route_batch="lane"),
        "union": Engine(mode="fused", route_batch="union"),
    }

    # warm every executable and pin bit-identity before timing anything
    res_u = engines["union"].run_batch(prog, pg, queries)
    res_l = engines["lane"].run_batch(prog, pg, queries)
    serial = [engines["serial"].run_batch(prog, pg, [s]) for s in queries]
    for qi in range(q):
        want = np.asarray(serial[qi].outputs[0])
        np.testing.assert_array_equal(np.asarray(res_u.outputs[qi]), want)
        np.testing.assert_array_equal(np.asarray(res_l.outputs[qi]), want)
        assert int(res_u.query_steps[qi]) == int(serial[qi].query_steps[0])
        assert res_u.query_bytes(qi) == serial[qi].query_bytes(0)

    t = {
        "serial": min(_timed(lambda: [engines["serial"].run_batch(
            prog, pg, [s]) for s in queries]) for _ in range(repeats)),
        "lane": min(_timed(lambda: engines["lane"].run_batch(
            prog, pg, queries)) for _ in range(repeats)),
        "union": min(_timed(lambda: engines["union"].run_batch(
            prog, pg, queries)) for _ in range(repeats)),
    }
    row = {
        "graph_n": graph.n,
        "q": q,
        "channel_class": spec.channel_class,
        "supersteps_batched": int(res_u.steps),
        "wall_s": t,
        "queries_per_s": {k: q / v for k, v in t.items()},
        "speedup_union": t["serial"] / t["union"],
        "speedup_lane": t["serial"] / t["lane"],
        "union_vs_lane": t["lane"] / t["union"],
        "outputs_match": True,
    }
    print(f"  {key:14s} serial {q / t['serial']:8.1f} q/s   "
          f"lane {q / t['lane']:8.1f} q/s   "
          f"union {q / t['union']:8.1f} q/s   "
          f"union speedup {row['speedup_union']:6.2f}x "
          f"(vs lane {row['union_vs_lane']:.2f}x)")
    return row


def run(scale: int = 12, q: int = 32, repeats: int = 3, keys=DEFAULT_KEYS):
    out = {"scale": scale, "workers": W, "q": q, "repeats": repeats,
           "mode": "fused", "programs": {}}
    for key in keys:
        out["programs"][key] = _bench_program(key, scale, q, repeats)
    head_key = (HEADLINE_PROGRAM if HEADLINE_PROGRAM in out["programs"]
                else next(iter(out["programs"])))
    head = out["programs"][head_key]
    out["headline"] = {
        "program": head_key,
        "scale": scale,
        "q": q,
        "queries_per_s_serial": head["queries_per_s"]["serial"],
        "queries_per_s_union": head["queries_per_s"]["union"],
        "speedup_union": head["speedup_union"],
        "speedup_lane": head["speedup_lane"],
        "union_vs_lane": head["union_vs_lane"],
        "target": TARGET,
        "meets_target": head["speedup_union"] >= TARGET,
    }
    print(f"  headline: {head_key} {head['speedup_union']:.2f}x "
          f"union-vs-serial (target {TARGET}x) at scale {scale}, Q={q}")
    return out


def run_and_write(scale: int = 12, q: int = 32, repeats: int = 3,
                  keys=DEFAULT_KEYS,
                  out_path: str = "BENCH_routed_batching.json"):
    print(f"== Routed-channel batching (scale {scale}, W={W}, Q={q}) ==")
    out = run(scale, q, repeats, keys)
    from benchmarks import common
    out["provenance"] = common.provenance()
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--keys", default=",".join(DEFAULT_KEYS),
                    help="comma list of routed batched registry keys")
    ap.add_argument("--out", default="BENCH_routed_batching.json")
    args = ap.parse_args()
    run_and_write(args.scale, args.queries, args.repeats,
                  tuple(args.keys.split(",")), args.out)


if __name__ == "__main__":
    main()
