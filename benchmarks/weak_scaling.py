"""Weak-scaling benchmark: per-device throughput as devices x scale grow.

    PYTHONPATH=src python -m benchmarks.weak_scaling [--scale 12]
        [--devices 1,2,4] [--repeats 3] [--out BENCH_weak_scaling.json]

Weak scaling holds the per-device problem size fixed: at D devices the
R-MAT scale is ``scale + log2(D)`` (2x vertices and edges per doubling),
the mesh is a real forced-D-device CPU ``shard_map`` mesh, and W = D.

Metric honesty: the forced host devices **time-share one physical
socket**, so at D devices each device's fair share of the machine is
1/D — perfect weak scaling keeps the *aggregate* problem throughput
(edges solved per wall second, ``m / wall``) flat as problem and device
count double together, which is exactly "per-device throughput held"
once each device is granted its 1/D socket share. The headline
``per_device_ratio`` is therefore aggregate throughput at D_max divided
by the tuned single-device run's aggregate throughput; both
configurations are measured against that same single-device reference.

Two configurations per device count:

  degree+mirror  the ``degree`` partitioner with ``mirror_threshold=
                 "auto"`` hub mirroring — the tentpole path. Its output
                 is asserted bit-identical to the unmirrored run before
                 anything is reported.
  random         the degree-blind baseline: whichever worker draws the
                 R-MAT hubs carries their whole cut — its remote message
                 volume blows up with D (``msg_ratio_random`` in the
                 headline) and its efficiency lands below target.

Each device count runs in a subprocess because
``--xla_force_host_platform_device_count`` must be set before jax
initializes. The child prints its measurements as one JSON line behind
a marker; the parent aggregates, stamps provenance, and writes the
``BENCH_weak_scaling.json`` artifact (schema pinned by
``benchmarks.check_schema``; smoke-run by ``scripts/tier1.sh``).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

PROGRAM = "wcc:switch"
DATASET = "social"          # rmat ef8 symmetrized — the hubby regime
TARGET = 0.75               # efficiency at Dmax vs tuned single-device
CHILD_MARKER = "WEAK-SCALING-CHILD-JSON:"


def child(devices: int, scale: int, repeats: int, seed: int) -> None:
    """Measure one device count (runs under forced-device XLA flags)."""
    import time

    import jax
    import numpy as np

    from benchmarks import common
    from repro.algorithms import REGISTRY
    from repro.graph import pgraph
    from repro.pregel.engine import Engine

    assert jax.device_count() == devices, jax.devices()
    mesh = jax.make_mesh((devices,), ("workers",))
    spec = REGISTRY[PROGRAM]
    g = common.dataset(DATASET, scale)
    prog = spec.factory(**spec.inputs(g, seed))
    eng = Engine(backend="shard_map", mesh=mesh)

    def measure(partitioner: str, thr):
        pg = pgraph.partition_graph(
            g, devices, partitioner, build=spec.build,
            mirror_threshold=pgraph.resolve_mirror_threshold(g, thr))
        res = eng.run(prog, pg)                      # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = eng.run(prog, pg)
            best = min(best, time.perf_counter() - t0)
        return pg, res, best

    rows = []
    pg_m, res_m, t_m = measure("degree", "auto")
    pg_0, res_0, _ = measure("degree", None)
    bit_identical = bool(
        np.array_equal(np.asarray(res_m.output), np.asarray(res_0.output))
        and res_m.steps == res_0.steps)
    pg_r, res_r, t_r = measure("random", None)

    def row(config, pg, res, wall):
        # problem throughput: edges solved per wall second. On one
        # time-shared socket this is the per-device rate times D, so a
        # flat curve = per-device throughput held at each device's 1/D
        # socket share (see module docstring). Convergence speed counts:
        # a partitioner that makes wcc take extra supersteps pays for it.
        thr = g.num_edges / wall
        return {
            "config": config, "devices": devices, "scale": scale,
            "n": g.n, "m": g.num_edges, "steps": res.steps,
            "runtime_s": round(wall, 4),
            "message_MB": round(res.total_bytes / 1e6, 4),
            "throughput": round(thr, 1),
            "throughput_per_device": round(thr / devices, 1),
            "hub_cap": pg.scatter_out.hub_cap if pg.scatter_out else 0,
            "route_cap": pg.route_cap,
        }

    rows.append(row("degree+mirror", pg_m, res_m, t_m))
    rows.append(row("random", pg_r, res_r, t_r))
    print(CHILD_MARKER + json.dumps(
        {"rows": rows, "bit_identical": bit_identical}))


def run_child(devices: int, scale: int, repeats: int, seed: int) -> dict:
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (str(root / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.weak_scaling", "--child",
           "--devices", str(devices), "--scale", str(scale),
           "--repeats", str(repeats), "--seed", str(seed)]
    proc = subprocess.run(cmd, env=env, cwd=str(root), text=True,
                          capture_output=True, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"weak_scaling child D={devices} failed:\n{proc.stdout}"
            f"\n{proc.stderr}")
    for line in proc.stdout.splitlines():
        if line.startswith(CHILD_MARKER):
            return json.loads(line[len(CHILD_MARKER):])
    raise RuntimeError(f"weak_scaling child D={devices}: no result marker")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12,
                    help="R-MAT scale at 1 device (+log2(D) per doubling)")
    ap.add_argument("--devices", default="1,2,4",
                    help="comma-separated device counts (powers of two)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_weak_scaling.json")
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.child:
        child(int(args.devices), args.scale, args.repeats, args.seed)
        return 0

    devices = sorted(int(d) for d in args.devices.split(","))
    rows, bit_ok = [], True
    for d in devices:
        scale_d = args.scale + (d.bit_length() - 1)  # + log2(d)
        print(f"== D={d} scale={scale_d} ==")
        out = run_child(d, scale_d, args.repeats, args.seed)
        bit_ok &= out["bit_identical"]
        for r in out["rows"]:
            print(f"  {r['config']:14s} {r['throughput']:12.0f} edges/s "
                  f"steps {r['steps']}  {r['runtime_s']:.3f}s  "
                  f"msg {r['message_MB']:.2f} MB")
        rows.extend(out["rows"])

    def at(config: str, d: int) -> dict:
        return next(r for r in rows
                    if r["config"] == config and r["devices"] == d)

    # everything is measured against the tuned single-device run
    base = at("degree+mirror", devices[0])["throughput"]
    eff_mirror = round(at("degree+mirror", devices[-1])["throughput"] / base, 4)
    eff_random = round(at("random", devices[-1])["throughput"] / base, 4)
    mb_m = at("degree+mirror", devices[-1])["message_MB"]
    mb_r = at("random", devices[-1])["message_MB"]
    headline = {
        "program": PROGRAM, "dataset": DATASET,
        "devices_max": devices[-1],
        "per_device_ratio": eff_mirror,
        "random_ratio": eff_random,
        "msg_ratio_random": round(mb_r / mb_m, 4) if mb_m else 0.0,
        "target": TARGET,
        "meets_target": eff_mirror >= TARGET,
        "bit_identical": bit_ok,
    }
    from benchmarks import common
    data = {
        "scale": args.scale, "devices": devices, "repeats": args.repeats,
        "seed": args.seed, "program": PROGRAM, "dataset": DATASET,
        "rows": rows, "headline": headline,
        "provenance": common.provenance(),
    }
    pathlib.Path(args.out).write_text(json.dumps(data, indent=2) + "\n")
    print(f"headline: per-device ratio {headline['per_device_ratio']} "
          f"(random {headline['random_ratio']}, target >= {TARGET}) "
          f"bit_identical={bit_ok} -> {args.out}")
    return 0 if (headline["meets_target"] and bit_ok) else 1


if __name__ == "__main__":
    sys.exit(main())
