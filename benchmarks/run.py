"""Benchmark harness entry point — one function per paper table.

    PYTHONPATH=src python -m benchmarks.run [--scale N] [--full] [--csv out]

Default scale is CPU-friendly (~8k vertices / ~100k edges per graph);
--full uses 4x larger graphs. Emits the per-table results as text plus a
final CSV block, and (if results/dryrun exists) the roofline table.
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--csv", default=None)
    ap.add_argument("--tables", default="4,5a,5b,5c,6,7,sssp,fusion",
                    help="comma list: 4,5a,5b,5c,6,7,sssp,fusion")
    args = ap.parse_args()

    scale = args.scale or (15 if args.full else 13)

    from benchmarks import common, tables

    todo = set(args.tables.split(","))
    if "4" in todo:
        tables.table4_basic_channels(scale)
    if "5a" in todo:
        tables.table5_scatter_combine(scale)
    if "5b" in todo:
        tables.table5_request_respond(scale)
    if "5c" in todo:
        tables.table5_propagation(scale)
    if "6" in todo:
        tables.table6_sv_composition(scale)
    if "7" in todo:
        tables.table7_minlabel_scc(scale - 1)
    if "sssp" in todo:
        tables.bonus_sssp(scale - 1)
    if "fusion" in todo:
        from benchmarks import superstep_fusion
        print()
        superstep_fusion.run_and_write(scale + 1)

    stats = tables.session_stats()
    hit_rate = stats["cache_hits"] / max(stats["runs"], 1)
    print("\nengine session (compile-once across tables):", stats,
          f"(per-run cache hits: {stats['cache_hits']}/{stats['runs']}"
          f" = {hit_rate:.0%})")

    print("\n== CSV ==")
    common.print_csv()
    if args.csv:
        with open(args.csv, "w") as f:
            common.print_csv(f)

    if os.path.isdir("results/dryrun"):
        print("\n== Roofline (from dry-run artifacts) ==")
        from benchmarks import roofline
        roofline.main()


if __name__ == "__main__":
    main()
