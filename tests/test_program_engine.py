"""VertexProgram + Engine: compile-once sessions.

The acceptance property: two same-shape graphs run through one Engine
pay for exactly ONE compile — the second run is a cache hit, bit-exact
against what a fresh compile would produce (the sweep in
test_algorithms.py covers fresh-vs-legacy parity; here we pin the
session/caching behavior itself).
"""
import numpy as np
import pytest

from repro.algorithms import REGISTRY, get_program, resolve, sssp
from repro.graph import generators as gen, oracles, pgraph
from repro.pregel.engine import Engine
from repro.pregel import runtime


def _weighted_pair(scale=8):
    """Two graphs with identical topology (hence identical shape
    signature) but different edge weights — different answers, one
    executable."""
    g1 = gen.rmat(scale, edge_factor=4, seed=5, weighted=True)
    rng = np.random.default_rng(99)
    w2 = rng.random(len(g1.edges)).astype(np.float32)
    g2 = gen.EdgeList(g1.n, g1.edges, w2, g1.directed, "alt-weights")
    build = ("prop_out", "raw_out")
    pg1 = pgraph.partition_graph(g1, 4, "random", build=build)
    pg2 = pgraph.partition_graph(g2, 4, "random", build=build)
    return (g1, pg1), (g2, pg2)


@pytest.mark.parametrize("mode", ("fused", "host", "chunked"))
def test_one_compile_for_second_same_shape_graph(mode):
    (g1, pg1), (g2, pg2) = _weighted_pair()
    assert runtime.graph_signature(pg1) == runtime.graph_signature(pg2)

    eng = Engine(mode=mode, chunk_size=4)
    prog = sssp.program("basic", source=0)
    r1 = eng.run(prog, pg1)
    r2 = eng.run(prog, pg2)

    # exactly one compile total: the second run reports a cache hit
    assert eng.compiles == 1 and eng.cache_hits == 1
    assert not r1.cache_hit and r2.cache_hit
    assert r1.engine_compiles == 1 and r2.engine_compiles == 1
    assert r2.engine_cache_hits == 1
    assert r1.compile_time_s > 0.0 and r2.compile_time_s == 0.0

    # the shared executable answers each instance correctly
    for g, r in ((g1, r1), (g2, r2)):
        want = oracles.sssp_oracle(g, source=0)
        finite = ~np.isinf(want)
        np.testing.assert_allclose(r.output[finite], want[finite], rtol=1e-5)
    assert not np.array_equal(r1.output, r2.output)


def test_pow2_cap_bucketing_shares_compiles():
    """Slot caps are bucketed to the next power of two, so graphs whose
    raw per-worker counts differ slightly (same topology class, a few
    edges more or less) land on identical caps — one Engine compile
    serves both, and the second run is a cache hit."""
    g1 = gen.rmat(8, edge_factor=4, seed=5)
    g2 = gen.EdgeList(g1.n, g1.edges[:-5], None, g1.directed, "trimmed")
    build = ("scatter_out", "raw_out")
    pg1 = pgraph.partition_graph(g1, 4, "random", build=build)
    pg2 = pgraph.partition_graph(g2, 4, "random", build=build)
    # the caps are pow2-bucketed...
    for plan in (pg1.scatter_out, pg2.scatter_out):
        for cap in (plan.e_cap, plan.u_cap, plan.slot_cap):
            assert cap & (cap - 1) == 0, cap
    # ...and the signature (hence the compiled executable) is shared
    assert runtime.graph_signature(pg1) == runtime.graph_signature(pg2)

    eng = Engine()
    prog = get_program("wcc:basic")
    r1 = eng.run(prog, pg1)
    r2 = eng.run(prog, pg2)
    assert eng.compiles == 1 and eng.cache_hits == 1
    assert not r1.cache_hit and r2.cache_hit
    # the cache hit is bit-identical to what a fresh compile would give
    fresh = Engine().run(prog, pg2)
    np.testing.assert_array_equal(r2.output, fresh.output)
    assert r2.bytes_by_channel == fresh.bytes_by_channel


def test_compile_supersteps_executes_across_same_shape_graphs():
    """The low-level API itself must honor the reuse contract: an
    executable compiled against one graph runs any same-signature graph
    (host-only identity statics are scrubbed out of the lowered treedef)."""
    (g1, pg1), (g2, pg2) = _weighted_pair()
    prog = sssp.program("basic", source=0)
    exe = runtime.compile_supersteps(pg1, prog.step, prog.init(pg1),
                                     max_steps=prog.max_steps)
    for g, pg in ((g1, pg1), (g2, pg2)):
        res = exe.execute(pg, prog.init(pg))
        want = oracles.sssp_oracle(g, source=0)
        finite = ~np.isinf(want)
        np.testing.assert_allclose(pg.to_global(res.state["dist"])[finite],
                                   want[finite], rtol=1e-5)


def test_repeat_run_hits_cache_and_matches():
    spec = REGISTRY["wcc:basic"]
    g = spec.make_graph(8, 0)
    pg = pgraph.partition_graph(g, 4, "random", build=spec.build)
    prog = spec.make(g)
    eng = Engine()
    results = eng.run_many(prog, [pg, pg])
    r1, r2 = results
    assert eng.compiles == 1 and eng.cache_hits == 1
    # run_many exposes the per-item compile-cache outcome
    assert results.cache_hits == [False, True] and results.hit_count == 1
    assert eng.stats()["runs"] == 2
    np.testing.assert_array_equal(r1.output, r2.output)
    assert r1.bytes_by_channel == r2.bytes_by_channel
    assert r1.program == r2.program == "wcc:basic"


def test_batch_cap_bucketing_shares_compiles():
    """run_batch keys its compile on the pow2-bucketed batch cap: Q=5 and
    Q=7 both lower at cap 8 and share one executable, while a Q=3 batch
    lands in the cap-4 bucket — a batch sweep spanning two buckets pays
    exactly two compiles, and every batch answers identically."""
    spec = REGISTRY["sssp:basic"]
    g = spec.make_graph(8, 0)
    pg = pgraph.partition_graph(g, 4, "random", build=spec.build)
    prog = get_program("sssp:basic")
    sources = [0, 3, 17, 100, 42, 9, 2]
    eng = Engine()
    r5 = eng.run_batch(prog, pg, sources[:5])   # cap 8: compile
    r7 = eng.run_batch(prog, pg, sources)       # cap 8: cache hit
    r3 = eng.run_batch(prog, pg, sources[:3])   # cap 4: compile
    assert not r5.cache_hit and r7.cache_hit and not r3.cache_hit
    assert eng.compiles == 2 and eng.cache_hits == 1
    for qi in range(3):
        np.testing.assert_array_equal(
            np.asarray(r5.outputs[qi]), np.asarray(r7.outputs[qi]))
        np.testing.assert_array_equal(
            np.asarray(r5.outputs[qi]), np.asarray(r3.outputs[qi]))


def test_different_shape_recompiles():
    spec = REGISTRY["wcc:basic"]
    eng = Engine()
    prog = get_program("wcc:basic")
    for scale in (7, 8):
        g = spec.make_graph(scale, 0)
        pg = pgraph.partition_graph(g, 4, "random", build=spec.build)
        eng.run(prog, pg)
    assert eng.compiles == 2 and eng.cache_hits == 0


def test_max_steps_is_part_of_the_cache_key():
    spec = REGISTRY["wcc:basic"]
    g = spec.make_graph(8, 0)
    pg = pgraph.partition_graph(g, 4, "random", build=spec.build)
    prog = get_program("wcc:basic")
    eng = Engine()
    full = eng.run(prog, pg)
    cut = eng.run(prog, pg, max_steps=2)
    assert eng.compiles == 2  # a different superstep budget is a new loop
    assert cut.steps == 2 and not cut.halted
    assert full.halted


def test_get_program_is_memoized():
    assert get_program("wcc:switch") is get_program("wcc:switch")
    assert get_program("wcc:switch") is not get_program("wcc:basic")
    # knobs are part of the memo key
    assert (get_program("pagerank:scatter", iters=5)
            is not get_program("pagerank:scatter"))
    # resolve() accepts bare algorithm names
    assert resolve("wcc").variant == "prop"
    with pytest.raises(KeyError, match="unknown program"):
        resolve("nope")


def test_engine_rejects_unknown_mode():
    with pytest.raises(ValueError, match="unknown execution mode"):
        Engine(mode="warp")


def test_program_repr_and_channels():
    prog = get_program("sv:composed")
    names = prog.channel_names()
    assert "sv/pointer/request" in names and "sv/jump" in names
    assert "sv:composed" in repr(prog)
    assert get_program("wcc:basic").channel_names() == ()
