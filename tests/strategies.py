"""Shared random-instance generators and hypothesis strategies.

One home for the ad-hoc message/graph generators that used to be copied
between test modules: ``test_dataplane.py`` and ``test_channels.py``
draw their random message sets from here, and the hypothesis strategy
objects give the property tests one consistent parameter space. The
hypothesis import is optional (PR 1 convention — the suite must collect
without it): the plain numpy generators always work, and the strategy
objects exist only when ``HAVE_HYPOTHESIS`` is true.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# canonical small-shard geometry used across the channel-level tests
W, N_LOC = 4, 16

try:
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev env without hypothesis
    st = None
    HAVE_HYPOTHESIS = False


def random_messages(seed: int, m: int, w: int = W, n_loc: int = N_LOC,
                    valid_frac: float = 0.7):
    """Random routed-message set with a pytree payload, as device arrays:
    ``(dst (w, m) i32, valid (w, m) bool, payload {f: (w, m) f32,
    i2: (w, m, 2) i32})`` — the data-plane parity tests' instance."""
    rng = np.random.default_rng(seed)
    dst = jnp.asarray(rng.integers(0, w * n_loc, (w, m)).astype(np.int32))
    valid = jnp.asarray(rng.random((w, m)) < valid_frac)
    payload = {
        "f": jnp.asarray(rng.normal(size=(w, m)).astype(np.float32)),
        "i2": jnp.asarray(rng.integers(0, 99, (w, m, 2)).astype(np.int32)),
    }
    return dst, valid, payload


def random_scalar_messages(seed: int, m: int, w: int = W, n_loc: int = N_LOC,
                           valid_frac: float = 0.7):
    """Random scalar-valued message set as HOST numpy arrays:
    ``(dst (w, m) i32, valid (w, m) bool, vals (w, m) f32)`` — the
    channel-vs-bruteforce tests index these directly in their oracles."""
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, w * n_loc, (w, m)).astype(np.int32)
    valid = rng.random((w, m)) < valid_frac
    vals = rng.normal(size=(w, m)).astype(np.float32)
    return dst, valid, vals


if HAVE_HYPOTHESIS:
    #: any rng seed
    seeds = st.integers(0, 2**31 - 1)
    #: messages per worker, sized for fast channel-level cases
    message_counts = st.integers(1, 60)
    #: a probability knob (valid fraction, capacity fraction, ...)
    fractions = st.floats(0.0, 1.0)
