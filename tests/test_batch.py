"""The batched query plane: ``Engine.run_batch`` vs Q independent runs.

The acceptance property, swept straight off the registry: for every
query-parametric program (``repro.algorithms.BATCHED``) in every
execution mode, a batched run's per-query outputs, step counts and
per-channel traffic are bit-identical to Q independent ``Engine.run``
calls — batching reshapes execution, never answers. Plus the pow2
batch-cap bucketing contract and the run_batch API surface; the
hypothesis property pins the Q=1 degenerate case.
"""
import functools

import numpy as np
import pytest

import strategies
from repro.algorithms import BATCHED, REGISTRY
from repro.graph import pgraph
from repro.pregel.engine import Engine, bucket_queries

SEED = 0
W = 4
NQ = 5  # pads into the cap-8 bucket -> exercises the padded lanes
CHUNK = 3
MODES = ("fused", "host", "chunked")


@functools.lru_cache(maxsize=None)
def problem(key):
    """(graph, pg, inputs, program, queries) for a batched registry key —
    cached so the mode sweep shares one partition and program instance."""
    spec = REGISTRY[key]
    graph = spec.make_graph(spec.test_scale, SEED)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    inputs = spec.inputs(graph, SEED)
    return graph, pg, inputs, spec.factory(**inputs), spec.queries(
        graph, SEED, NQ)


@functools.lru_cache(maxsize=None)
def serial_reference(key, mode):
    """Q independent Engine.run results for a batched key (cached across
    the assertions that compare against them)."""
    spec = REGISTRY[key]
    _, pg, inputs, _, queries = problem(key)
    eng = Engine(mode=mode, chunk_size=CHUNK)
    out = []
    for qv in queries:
        prog_q = spec.factory(**{**inputs, spec.query_knob: qv})
        out.append(eng.run(prog_q, pg))
    return out


# the smoke tier keeps one fused entry per channel family (sssp:basic =
# dynamically routed, pagerank:personal = static plan); everything else
# is @slow
SMOKE = {"sssp:basic", "pagerank:personal"}


def sweep_params():
    for key in BATCHED:
        for mode in MODES:
            slow = mode != "fused" or key not in SMOKE
            yield pytest.param(key, mode,
                               marks=[pytest.mark.slow] if slow else [],
                               id=f"{key}-{mode}")


@pytest.mark.parametrize("key,mode", sweep_params())
def test_batched_matches_serial_runs(key, mode):
    _, pg, _, prog, queries = problem(key)
    res = Engine(mode=mode, chunk_size=CHUNK).run_batch(prog, pg, queries)

    assert res.num_queries == len(queries)
    assert len(res.outputs) == len(queries) and res.output is res.outputs
    assert res.steps == int(res.query_steps.max())
    for qi, serial in enumerate(serial_reference(key, mode)):
        np.testing.assert_array_equal(
            np.asarray(res.outputs[qi]), np.asarray(serial.output))
        assert int(res.query_steps[qi]) == serial.steps
        assert bool(res.query_halted[qi]) == serial.halted
        assert res.query_bytes(qi) == serial.bytes_by_channel
        assert res.query_msgs(qi) == serial.msgs_by_channel
    # the across-query totals are exactly the per-query sums
    for name, per_q in res.query_bytes_by_channel.items():
        assert res.bytes_by_channel[name] == int(per_q.sum())
    for name, per_q in res.query_msgs_by_channel.items():
        assert res.msgs_by_channel[name] == int(per_q.sum())


def test_route_batch_lane_matches_union_and_cache_key():
    """The routed-channel batching knob: ``route_batch="lane"`` (Q
    per-lane route passes) and ``"union"`` (one shared union-frontier
    pass) produce bit-identical per-query results on a routed program,
    each strategy is its own compile-cache entry, and the RunResult is
    stamped with the strategy that produced it."""
    _, pg, _, prog, queries = problem("sssp:basic")
    eng_u = Engine(mode="fused", route_batch="union")
    eng_l = Engine(mode="fused", route_batch="lane")
    ru = eng_u.run_batch(prog, pg, queries)
    rl = eng_l.run_batch(prog, pg, queries)
    assert ru.route_batch == "union" and rl.route_batch == "lane"
    assert eng_u.compiles == 1 and eng_l.compiles == 1
    for qi in range(len(queries)):
        np.testing.assert_array_equal(
            np.asarray(ru.outputs[qi]), np.asarray(rl.outputs[qi]))
        assert ru.query_bytes(qi) == rl.query_bytes(qi)
        assert ru.query_msgs(qi) == rl.query_msgs(qi)
    np.testing.assert_array_equal(np.asarray(ru.query_steps),
                                  np.asarray(rl.query_steps))


@pytest.mark.parametrize("route_batch", ("union", "lane"))
def test_pad_lanes_never_reach_the_wire(route_batch):
    """Regression (pad/halt traffic fix): NQ=5 pads into the cap-8
    bucket, so three pad lanes (replays of query 0) and every
    post-convergence halted lane ride along each superstep. Neither may
    occupy shared wire slots or be charged: the run totals are exactly
    the per-real-query sums, on both batching strategies."""
    _, pg, _, prog, queries = problem("sssp:basic")
    res = Engine(mode="fused", route_batch=route_batch).run_batch(
        prog, pg, queries)
    assert res.num_queries == NQ
    for name, tot in res.bytes_by_channel.items():
        assert tot == sum(res.query_bytes(q)[name] for q in range(NQ)), \
            (route_batch, name)
    for name, tot in res.msgs_by_channel.items():
        assert tot == sum(res.query_msgs(q)[name] for q in range(NQ)), \
            (route_batch, name)
    assert res.total_bytes == sum(
        sum(res.query_bytes(q).values()) for q in range(NQ))


@pytest.mark.parametrize("route_batch", ("union", "lane"))
def test_pad_lanes_are_fully_dead(route_batch):
    """Regression (pad-lane seam fix): pad lanes used to *replay query
    0* — stepping its frontier a second time through the union route
    pass and burning wire slots for work that was sliced away. Pads now
    start halted (``query_live=False`` end to end): they never step and
    are never charged, and the RunResult's dead-pad audit fields prove
    it — NQ=5 pads into the cap-8 bucket, so exactly 3 pad lanes with
    zero steps, zero bytes, zero messages (= zero wire slots)."""
    _, pg, _, prog, queries = problem("sssp:basic")
    for mode in ("fused", "chunked"):
        res = Engine(mode=mode, chunk_size=CHUNK,
                     route_batch=route_batch).run_batch(prog, pg, queries)
        assert res.num_pad_lanes == 3, (mode, route_batch)
        assert res.pad_steps == 0, (mode, route_batch)
        assert res.pad_bytes == 0, (mode, route_batch)
        assert res.pad_msgs == 0, (mode, route_batch)


def test_bucket_queries_pow2():
    assert [bucket_queries(q) for q in (1, 2, 3, 4, 5, 20, 27, 32, 33)] == \
        [1, 2, 4, 4, 8, 32, 32, 32, 64]
    with pytest.raises(ValueError, match="at least one query"):
        bucket_queries(0)


def test_run_batch_rejects_programs_without_query_axis():
    from repro.algorithms import get_program
    spec = REGISTRY["wcc:basic"]
    g = spec.make_graph(7, SEED)
    pg = pgraph.partition_graph(g, W, "random", build=spec.build)
    with pytest.raises(ValueError, match="no query axis"):
        Engine().run_batch(get_program("wcc:basic"), pg, [0, 1])


if strategies.HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    _Q1_ENGINE = Engine()  # shared so the batched side compiles once

    @pytest.mark.slow
    @settings(max_examples=6, deadline=None)
    @given(source=st.integers(0, 255))
    def test_run_batch_q1_bit_identical_to_run(source):
        """The degenerate batch: run_batch with Q=1 is Engine.run, bit
        for bit (output, steps, halt, per-channel traffic)."""
        _, pg, inputs, prog, _ = problem("sssp:basic")
        spec = REGISTRY["sssp:basic"]
        rb = _Q1_ENGINE.run_batch(prog, pg, [source])
        rs = _Q1_ENGINE.run(
            spec.factory(**{**inputs, spec.query_knob: source}), pg)
        np.testing.assert_array_equal(
            np.asarray(rb.outputs[0]), np.asarray(rs.output))
        assert rb.steps == rs.steps and rb.halted == rs.halted
        assert rb.query_bytes(0) == rs.bytes_by_channel
        assert rb.bytes_by_channel == rs.bytes_by_channel
        assert rb.msgs_by_channel == rs.msgs_by_channel
