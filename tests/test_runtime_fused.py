"""Execution-mode parity: the host loop, the fused lax.while_loop and the
chunked lax.scan runtime must be bit-identical in results, step counts and
per-channel traffic accounting — the fused modes only remove host
round-trips, never change semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import pointer_jumping, sv, wcc
from repro.graph import generators as gen, pgraph
from repro.pregel import runtime

MODES = ("host", "fused", "chunked")


@pytest.fixture(scope="module")
def pg_small():
    g = gen.rmat(8, edge_factor=4, seed=11).symmetrized()
    return pgraph.partition_graph(
        g, 4, "random", build=("scatter_out", "prop_out", "raw_out")
    )


def _run_all_modes(run_fn):
    out = {}
    for mode in MODES:
        # chunk_size=3 forces several host round-trips in chunked mode
        out[mode] = run_fn(mode)
    return out


@pytest.mark.parametrize("variant", ["basic", "both"])
@pytest.mark.slow
def test_sv_mode_parity(pg_small, variant):
    res = _run_all_modes(
        lambda m: sv.run(pg_small, variant=variant, mode=m, chunk_size=3)
    )
    lab_h, r_h = res["host"]
    for mode in ("fused", "chunked"):
        lab, r = res[mode]
        np.testing.assert_array_equal(lab_h, lab)
        assert r.steps == r_h.steps
        assert r.halted == r_h.halted
        assert r.bytes_by_channel == r_h.bytes_by_channel
        assert r.msgs_by_channel == r_h.msgs_by_channel


@pytest.mark.parametrize("variant", ["basic", "prop"])
def test_wcc_mode_parity(pg_small, variant):
    res = _run_all_modes(
        lambda m: wcc.run(pg_small, variant=variant, mode=m, chunk_size=3)
    )
    lab_h, r_h = res["host"]
    for mode in ("fused", "chunked"):
        lab, r = res[mode]
        np.testing.assert_array_equal(lab_h, lab)
        assert r.steps == r_h.steps
        assert r.halted == r_h.halted
        assert r.bytes_by_channel == r_h.bytes_by_channel
        assert r.msgs_by_channel == r_h.msgs_by_channel
        for leaf_h, leaf in zip(
            jax.tree_util.tree_leaves(r_h.state),
            jax.tree_util.tree_leaves(r.state),
        ):
            np.testing.assert_array_equal(np.asarray(leaf_h), np.asarray(leaf))


def test_pointer_jumping_mode_parity():
    n = 300
    par = gen.random_tree_parents(n, seed=3)
    empty = gen.EdgeList(n, np.zeros((0, 2), np.int64), None, True, "pj")
    pg = pgraph.partition_graph(empty, 4, "random", build=())
    res = _run_all_modes(
        lambda m: pointer_jumping.run(pg, par, mode=m, chunk_size=2)
    )
    roots_h, r_h = res["host"]
    for mode in ("fused", "chunked"):
        roots, r = res[mode]
        np.testing.assert_array_equal(roots_h, roots)
        assert (r.steps, r.halted) == (r_h.steps, r_h.halted)
        assert r.bytes_by_channel == r_h.bytes_by_channel
        assert r.msgs_by_channel == r_h.msgs_by_channel
    # fused = one dispatch; chunked = ceil(steps/2) (+1 if halt not seen)
    assert res["fused"][1].dispatches == 1
    assert res["chunked"][1].dispatches < res["host"][1].dispatches


def test_max_steps_without_halt_parity(pg_small):
    """Cut off before convergence: steps/halted must agree across modes."""
    for mode in MODES:
        _, r = wcc.run(pg_small, variant="basic", max_steps=2, mode=mode,
                       chunk_size=3)
        assert r.steps == 2 and not r.halted, mode


def _declared_step(ctx, gs, state, i):
    from repro.core import message as msg

    inc, got, ovf = msg.combined_send(
        ctx, gs.raw_out.dst_global, gs.raw_out.mask,
        state["x"][gs.raw_out.src_local], "min", capacity=ctx.n_loc,
    )
    return {"x": jnp.minimum(state["x"], inc)}, i >= 1, ovf


def test_explicit_channel_declaration(pg_small):
    """A full declaration runs; an undeclared-but-traced channel raises
    lazily (from ChannelContext.add_traffic during compilation)."""
    state0 = {"x": pg_small.global_ids().astype(jnp.int32)}
    for mode in MODES:
        res = runtime.run_supersteps(pg_small, _declared_step, state0,
                                     max_steps=2, mode=mode,
                                     channels=("combined_message",))
        assert res.steps == 2
    with pytest.raises(KeyError, match="not in the registry"):
        runtime.run_supersteps(pg_small, _declared_step, state0, max_steps=2,
                               channels=("not_a_channel",))
    # the other direction: a declared-but-never-traced channel would
    # report phantom zero rows forever — caught at compile time too
    with pytest.raises(ValueError, match="never traced"):
        runtime.run_supersteps(pg_small, _declared_step, state0, max_steps=2,
                               channels=("combined_message", "phantom"))


def test_declared_channels_skip_dry_trace(pg_small, monkeypatch):
    """channels= fully declares the registry: the eval_shape dry trace
    must not run at all. Without a declaration it still must."""
    state0 = {"x": pg_small.global_ids().astype(jnp.int32)}
    calls = []
    real = jax.eval_shape

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(jax, "eval_shape", spy)
    res = runtime.run_supersteps(pg_small, _declared_step, state0,
                                 max_steps=2, channels=("combined_message",))
    assert res.steps == 2
    assert not calls, "declared program still ran the eval_shape dry trace"

    runtime.run_supersteps(pg_small, _declared_step, state0, max_steps=2)
    assert calls, "undeclared program should discover via the dry trace"


def test_overflow_raises_in_all_modes():
    """Capacity overflow must surface as an error from every mode."""
    from repro.core import message as msg

    g = gen.rmat(6, edge_factor=4, seed=0).symmetrized()
    pg = pgraph.partition_graph(g, 4, "random", build=("raw_out",))

    def step(ctx, gs, state, i):
        # everyone messages vertex 0 with a tiny capacity => overflow
        deliv = msg.direct_send(
            ctx, jnp.zeros((ctx.n_loc,), jnp.int32), gs.v_mask,
            {"x": state["x"]}, capacity=2,
        )
        return {"x": state["x"]}, False, deliv.overflow

    state0 = {"x": jnp.zeros((pg.num_workers, pg.n_loc), jnp.float32)}
    for mode in MODES:
        with pytest.raises(RuntimeError, match="capacity overflow"):
            runtime.run_supersteps(pg, step, state0, max_steps=4, mode=mode,
                                   chunk_size=2)
