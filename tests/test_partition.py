"""Partitioner properties + hub-mirroring bit-identity.

The partitioner contract: a permutation ``new_of_old`` with contiguous
block ownership. The degree-aware partitioner must additionally bound
degree imbalance on power-law inputs, and the vectorized BFS must keep
``bfs_blocks``'s locality property. Hub mirroring
(``partition_graph(mirror_threshold=...)``) must never change final
vertex outputs for the lattice-combiner programs (wcc, sv, sssp) — only
the traffic profile — across fused/chunked modes and the real 4-device
shard_map mesh (subprocess, @slow).
"""
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.graph import generators as gen
from repro.graph import partition as pl
from repro.graph import pgraph

W = 8


def rmat():
    return gen.rmat(10, edge_factor=8, seed=1).symmetrized()


# ---------------------------------------------------------------------------
# partitioner property suite
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(pl.PARTITIONERS))
@pytest.mark.parametrize("graph_fn", [rmat, lambda: gen.grid2d(20),
                                      lambda: gen.chain(37)])
def test_partitioner_returns_permutation(name, graph_fn):
    g = graph_fn()
    p = pl.PARTITIONERS[name](g, W, seed=3)
    assert p.shape == (g.n,)
    assert np.array_equal(np.sort(p), np.arange(g.n))


@pytest.mark.parametrize("name", sorted(pl.PARTITIONERS))
def test_partitioner_deterministic_per_seed(name):
    g = rmat()
    a = pl.PARTITIONERS[name](g, W, seed=7)
    b = pl.PARTITIONERS[name](g, W, seed=7)
    assert np.array_equal(a, b)


def test_degree_partitioner_balances_degree_mass_on_rmat():
    g = rmat()
    deg = pl.degrees(g)
    n_loc, _ = pl._block_sizes(g.n, W)

    def per_worker_mass(p):
        owner = p // n_loc
        return np.bincount(owner[np.arange(g.n)], weights=deg, minlength=W)

    mass_deg = per_worker_mass(pl.degree(g, W))
    mass_rand = per_worker_mass(pl.random(g, W, seed=1))
    mean = deg.sum() / W
    # degree-aware: max worker within 10% of the mean; random on R-MAT
    # is at the mercy of the hub draw (strictly worse here)
    assert mass_deg.max() <= 1.10 * mean, mass_deg
    assert mass_deg.max() <= mass_rand.max()


def test_degree_partitioner_caps_no_worse_than_random():
    g = gen.rmat(12, edge_factor=8, seed=5).symmetrized()
    pg_deg = pgraph.partition_graph(g, W, "degree", build=("scatter_out",))
    pg_rnd = pgraph.partition_graph(g, W, "random", build=("scatter_out",))
    assert pg_deg.scatter_out.e_cap <= pg_rnd.scatter_out.e_cap
    assert pg_deg.route_cap <= pg_rnd.route_cap


def test_mirroring_bounds_replication_factor():
    # mirrors per hub <= W - 1, so total mirror slots are bounded by
    # (#exporting hubs) * (W - 1); replication factor over vertices stays
    # far below the all-workers worst case on R-MAT
    g = rmat()
    pg = pgraph.partition_graph(g, W, "degree", build=("scatter_out",),
                                mirror_threshold=32)
    plan = pg.scatter_out
    assert plan.hub_cap > 0 and plan.mirrored_edges > 0
    exported = int((np.asarray(plan.hub_local) < pg.n_loc).sum())
    assert exported * (W - 1) <= g.n  # replication factor bound
    # mirroring must strictly reduce wire entries on a hubby graph
    plain = pgraph.partition_graph(g, W, "degree", build=("scatter_out",))
    assert plan.remote_entries < plain.scatter_out.remote_entries


def test_bfs_blocks_locality_no_worse_on_grid():
    g = gen.grid2d(24)
    n_loc, _ = pl._block_sizes(g.n, W)

    def intra_fraction(p):
        s, d = p[g.edges[:, 0]], p[g.edges[:, 1]]
        return float((s // n_loc == d // n_loc).mean())

    bfs = intra_fraction(pl.bfs_blocks(g, W, seed=0))
    rand = intra_fraction(pl.random(g, W, seed=0))
    block = intra_fraction(pl.block(g, W, seed=0))
    # the locality partitioner must beat random and hold its own
    # against the identity block order on a grid
    assert bfs > rand
    assert bfs >= 0.8 * block


def test_unknown_partitioner_raises_value_error():
    g = gen.chain(16)
    with pytest.raises(ValueError, match="known partitioners"):
        pgraph.partition_graph(g, 4, "metis")


def test_plan_range_validation():
    from repro.pregel.errors import ExecutionError, PlanRangeError

    with pytest.raises(PlanRangeError):
        pgraph._check_int32_extent("test", 2**31)
    # structured: it is an ExecutionError carrying the offending extent
    try:
        pgraph._check_int32_extent("scatter_plan/pack_slot", 2**40)
    except ExecutionError as e:
        assert e.channels == ("scatter_plan/pack_slot",)
        assert e.superstep is None

    from repro.core import routing
    with pytest.raises(PlanRangeError):
        routing._check_slot_range(2**16, 2**16)
    routing._check_slot_range(8, 2**20)  # in range: no raise


# ---------------------------------------------------------------------------
# mirrored-vs-unmirrored bit-identity (vmap backend, fused + chunked)
# ---------------------------------------------------------------------------


def _pg(g, build, thr):
    return pgraph.partition_graph(g, W, "degree", build=build,
                                  mirror_threshold=thr)


@pytest.mark.parametrize("key", ["wcc:switch", "wcc:prop", "sv:composed",
                                 "sssp:basic", "sssp:prop"])
@pytest.mark.parametrize("mode", ["fused", "chunked"])
def test_mirrored_run_bit_identical(key, mode):
    from repro.algorithms import REGISTRY
    from repro.pregel.engine import Engine

    spec = REGISTRY[key]
    g = spec.make_graph(spec.test_scale, 0)
    prog = spec.factory(**spec.inputs(g, 0))
    r0 = Engine(mode=mode).run(prog, _pg(g, spec.build, None))
    rm = Engine(mode=mode).run(prog, _pg(g, spec.build, 8))
    np.testing.assert_array_equal(np.asarray(r0.output),
                                  np.asarray(rm.output))
    assert r0.steps == rm.steps and r0.halted == rm.halted


def test_auto_threshold_and_engine_cache_key_split():
    # "auto" resolves to a usable int; mirrored and unmirrored plans must
    # NOT share a compile (hub_cap is a shape static in graph_signature)
    from repro.pregel import runtime

    g = rmat()
    assert pgraph.resolve_mirror_threshold(g, "auto") >= 64
    s0 = runtime.graph_signature(_pg(g, ("scatter_out",), None))
    sm = runtime.graph_signature(_pg(g, ("scatter_out",), 32))
    assert s0 != sm
    # same build twice -> same signature (cache reuse across graphs)
    assert sm == runtime.graph_signature(_pg(g, ("scatter_out",), 32))


# ---------------------------------------------------------------------------
# the forced 4-device mesh (subprocess: XLA flags must precede jax init)
# ---------------------------------------------------------------------------

MESH_SCRIPT = r'''
import numpy as np, jax
assert jax.device_count() == 4, jax.devices()
from repro.algorithms import REGISTRY
from repro.graph import pgraph
from repro.pregel.engine import Engine

W = 4
mesh = jax.make_mesh((W,), ("workers",))
for key in ("wcc:switch", "sv:composed", "sssp:basic"):
    spec = REGISTRY[key]
    g = spec.make_graph(spec.test_scale, 0)
    prog = spec.factory(**spec.inputs(g, 0))
    def pg(thr):
        return pgraph.partition_graph(g, W, "degree", build=spec.build,
                                      mirror_threshold=thr)
    r0 = Engine(backend="shard_map", mesh=mesh).run(prog, pg(None))
    rm = Engine(backend="shard_map", mesh=mesh).run(prog, pg(8))
    np.testing.assert_array_equal(np.asarray(r0.output),
                                  np.asarray(rm.output))
    assert r0.steps == rm.steps, key
    print(key, "ok", r0.steps)
print("MESH-MIRROR-OK")
'''


@pytest.mark.slow
def test_mirrored_bit_identical_on_forced_mesh():
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (str(root / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=str(root))
    assert proc.returncode == 0, f"\n--- stdout:\n{proc.stdout}" \
                                 f"\n--- stderr:\n{proc.stderr}"
    assert "MESH-MIRROR-OK" in proc.stdout
