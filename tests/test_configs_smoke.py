"""Per-architecture smoke tests: reduced same-family config, one forward
and one train step on CPU, asserting output shapes and no NaNs. The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# model-config sweeps dominate suite time; excluded from the smoke tier
pytestmark = pytest.mark.slow

from repro.configs import registry as R
from repro.models import model as M
from repro.models import params as Pm
from repro.train import data as data_lib
from repro.train import train_step as ts
from repro.train.optimizer import AdamW

ARCHS = list(R.ARCHS.keys())


def make_batch(cfg, b, s, key=0):
    pipe = data_lib.SyntheticLM(cfg, seq_len=s, global_batch=b, seed=key)
    return pipe.batch_at(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = R.ARCHS[arch].smoke
    prm = Pm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)
    fwd_batch = {k: v for k, v in batch.items() if k != "labels"}
    logits, _ = M.forward(cfg, prm, fwd_batch)
    s_expect = 16 + (cfg.frontend_tokens
                     if cfg.frontend == "vision_patches" else 0)
    assert logits.shape == (2, s_expect, cfg.vocab)
    assert not np.isnan(np.asarray(logits)).any()


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = R.ARCHS[arch].smoke
    opt = AdamW(lr=1e-3)
    state = ts.init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(ts.make_train_step(cfg, opt, microbatches=1, remat=True))
    batch = make_batch(cfg, 2, 16)
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(
            jax.tree_util.tree_leaves(state.params)[:5],
            jax.tree_util.tree_leaves(
                ts.init_train_state(cfg, opt, jax.random.PRNGKey(0)).params
            )[:5],
        )
    )
    assert moved


@pytest.mark.parametrize("arch", ["chatglm3-6b", "mixtral-8x7b",
                                  "mamba2-130m", "jamba-1.5-large-398b",
                                  "qwen2-moe-a2.7b"])
def test_decode_matches_full_forward(arch):
    """Token-by-token decode == full forward (smoke config)."""
    cfg = R.ARCHS[arch].smoke
    prm = Pm.init_params(cfg, jax.random.PRNGKey(0))
    s = 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, s + 1), 0, cfg.vocab)
    full, _ = M.forward(cfg, prm, {"tokens": tokens})
    cache = M.init_cache(cfg, 2, s + 1)
    _, cache = M.forward(cfg, prm, {"tokens": tokens[:, :s]}, cache=cache)
    dlog, _ = M.forward(cfg, prm, {"tokens": tokens[:, s:s + 1]},
                        cache=cache, cache_pos=jnp.asarray(s))
    np.testing.assert_allclose(
        np.asarray(dlog[:, 0]), np.asarray(full[:, s]), rtol=2e-2, atol=2e-2
    )


def test_exact_configs_match_published_sizes():
    """Analytic parameter counts stay near the published model sizes."""
    expect = {
        "mamba2-130m": (0.10e9, 0.17e9),
        "chatglm3-6b": (5.5e9, 7e9),
        "granite-8b": (7.5e9, 9e9),
        "qwen2-7b": (7e9, 8.5e9),
        "mixtral-8x7b": (45e9, 48e9),
        "jamba-1.5-large-398b": (390e9, 405e9),
    }
    for arch, (lo, hi) in expect.items():
        n = R.ARCHS[arch].config.num_params()
        assert lo <= n <= hi, (arch, n)


def test_cells_cover_assignment():
    runnable = R.cells()
    skipped = [c for c in R.cells(True) if c[2]]
    assert len(runnable) + len(skipped) == 40
    # long_500k runs exactly for the sub-quadratic archs
    long_runs = {a for a, s, _ in runnable if s.name == "long_500k"}
    assert long_runs == {"mamba2-130m", "mixtral-8x7b", "jamba-1.5-large-398b"}
