"""Model-layer properties: SSD chunk invariance, SWA ring cache, MoE
dispatch vs dense oracle, SPMD MoE (shard_map) vs local MoE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.models import layers, mamba, model as M, params as Pm
from repro.models.config import ModelConfig


def test_ssd_chunk_size_invariance():
    """Chunked SSD must give identical results for any chunk size."""
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 64, 3, 4, 8
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    outs = {}
    for chunk in (8, 16, 32, 64):
        y, st_ = mamba.ssd_chunked(x, dt, a, bm, cm, chunk)
        outs[chunk] = (np.asarray(y), np.asarray(st_))
    for chunk in (16, 32, 64):
        np.testing.assert_allclose(outs[8][0], outs[chunk][0],
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(outs[8][1], outs[chunk][1],
                                   rtol=1e-4, atol=1e-5)


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step SSM recurrence."""
    rng = np.random.default_rng(1)
    b, s, h, p, n = 1, 32, 2, 3, 4
    x = rng.normal(size=(b, s, h, p)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (b, s, h)).astype(np.float32)
    a = -rng.uniform(0.5, 2.0, (h,)).astype(np.float32)
    bm = rng.normal(size=(b, s, n)).astype(np.float32)
    cm = rng.normal(size=(b, s, n)).astype(np.float32)
    y, _ = mamba.ssd_chunked(jnp.array(x), jnp.array(dt), jnp.array(a),
                             jnp.array(bm), jnp.array(cm), chunk=8)
    # naive
    state = np.zeros((b, h, p, n))
    y_ref = np.zeros((b, s, h, p))
    for t in range(s):
        da = np.exp(dt[:, t] * a[None, :])  # (b,h)
        state = state * da[..., None, None] + np.einsum(
            "bhp,bn->bhpn", x[:, t] * dt[:, t][..., None], bm[:, t])
        y_ref[:, t] = np.einsum("bhpn,bn->bhp", state, cm[:, t])
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(window=st.integers(4, 16), s=st.integers(20, 48),
       seed=st.integers(0, 1000))
def test_swa_decode_ring_cache_property(window, s, seed):
    """SWA decode through the ring cache == full forward with SWA mask."""
    cfg = ModelConfig("swa", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                      d_ff=64, vocab=53, attn_window=window, dtype="float32")
    prm = Pm.init_params(cfg, jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (1, s), 0, 53)
    full, _ = M.forward(cfg, prm, {"tokens": toks})
    # prefill s-4 then decode 4
    cut = s - 4
    cache = M.init_cache(cfg, 1, s)
    _, cache = M.forward(cfg, prm, {"tokens": toks[:, :cut]}, cache=cache)
    for i in range(4):
        dlog, cache = M.forward(cfg, prm, {"tokens": toks[:, cut+i:cut+i+1]},
                                cache=cache, cache_pos=jnp.asarray(cut + i))
        np.testing.assert_allclose(np.asarray(dlog[0, 0]),
                                   np.asarray(full[0, cut + i]),
                                   rtol=2e-3, atol=2e-3)


def test_moe_local_matches_dense_oracle():
    """With no capacity drops, sort-based MoE == explicit per-token expert
    mixture computed densely."""
    cfg = ModelConfig("m", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
                      d_ff=0, vocab=11, moe_experts=4, moe_top_k=2,
                      moe_ff=8, capacity_factor=8.0, dtype="float32")
    rng = np.random.default_rng(3)
    t, d, e, ff = 24, 16, 4, 8
    lp = {
        "router": jnp.asarray(rng.normal(size=(d, e)), jnp.float32),
        "moe_w1": jnp.asarray(rng.normal(size=(e, d, ff)), jnp.float32),
        "moe_w2": jnp.asarray(rng.normal(size=(e, ff, d)), jnp.float32),
        "moe_w3": jnp.asarray(rng.normal(size=(e, d, ff)), jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    got = layers.moe_local(cfg, lp, x)
    # oracle
    logits = np.asarray(x @ lp["router"])
    topi = np.argsort(-logits, axis=-1)[:, :2]
    topv = np.take_along_axis(logits, topi, axis=-1)
    w = np.exp(topv - topv.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    want = np.zeros((t, d), np.float32)
    for i in range(t):
        for j in range(2):
            eid = topi[i, j]
            h = np.asarray(x[i] @ lp["moe_w1"][eid])
            g = np.asarray(x[i] @ lp["moe_w3"][eid])
            act = h / (1 + np.exp(-h)) * g
            want[i] += w[i, j] * (act @ np.asarray(lp["moe_w2"][eid]))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_spmd_moe_matches_local():
    """shard_map MoE (1x1 mesh) == local MoE layer."""
    from repro.distributed.moe_spmd import make_spmd_moe
    from repro.launch.mesh import make_local_mesh

    cfg = ModelConfig("m", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
                      d_ff=0, vocab=11, moe_experts=4, moe_top_k=2,
                      moe_ff=8, moe_shared_ff=16, capacity_factor=8.0,
                      dtype="float32")
    rng = np.random.default_rng(4)
    d, e, ff = 16, 4, 8
    lp = {
        "router": jnp.asarray(rng.normal(size=(d, e)), jnp.float32),
        "moe_w1": jnp.asarray(rng.normal(size=(e, d, ff)), jnp.float32),
        "moe_w2": jnp.asarray(rng.normal(size=(e, ff, d)), jnp.float32),
        "moe_w3": jnp.asarray(rng.normal(size=(e, d, ff)), jnp.float32),
        "shared_w1": jnp.asarray(rng.normal(size=(d, 16)), jnp.float32),
        "shared_w2": jnp.asarray(rng.normal(size=(16, d)), jnp.float32),
        "shared_w3": jnp.asarray(rng.normal(size=(d, 16)), jnp.float32),
        "shared_gate": jnp.asarray(rng.normal(size=(d, 1)), jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(2, 6, d)), jnp.float32)
    want = layers.moe_layer(cfg, lp, x)
    mesh = make_local_mesh()
    moe = make_spmd_moe(cfg, mesh)
    got = jax.jit(lambda lp, x: moe(cfg, lp, x))(lp, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_remat_and_unroll_forward_identical():
    cfg = ModelConfig("r", n_layers=4, d_model=32, n_heads=4, n_kv_heads=2,
                      d_ff=64, vocab=31, dtype="float32")
    prm = Pm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 31)
    base, _ = M.forward(cfg, prm, {"tokens": toks})
    for kw in ({"remat": True}, {"unroll": True},
               {"remat": True, "unroll": True}):
        out, _ = M.forward(cfg, prm, {"tokens": toks}, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-5, atol=1e-6)
