"""The channel planner: Plan resolution, determinism, bit-identity.

The planner's contract (see ``src/repro/plan/planner.py``):

- deterministic: equal fingerprints give equal plans, across processes,
  calibration cache warm or cold;
- explicit wins: caller-set knobs are taken verbatim under every plan
  policy;
- bit-identity: a planned run's output equals the hand-set run with the
  same knobs — the planner selects among proven-identical
  implementations only;
- isolation: planning never pollutes the Engine compile cache or its
  ``stats()`` counters (probes are jitted outside the engine).
"""
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.algorithms import REGISTRY
from repro.core import compose
from repro.graph import generators as gen, pgraph
from repro.plan import Plan, Planner, manual_plan
from repro.pregel.engine import Engine


@pytest.fixture(autouse=True)
def _plan_cache(tmp_path, monkeypatch):
    """Every test gets a fresh calibration cache — no cross-test reuse,
    nothing written into the repo checkout."""
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plan_cache"))


def _problem(key="sssp:basic", scale=8, workers=4):
    spec = REGISTRY[key]
    graph = spec.make_graph(scale, 0)
    pg = pgraph.partition_graph(graph, workers, "random", build=spec.build)
    return spec, graph, pg, spec.factory(**spec.inputs(graph, 0))


# -- the dense_threshold knob (the one added to the unified resolver) ----

def test_dense_threshold_precedence(monkeypatch):
    assert compose.resolve_dense_threshold() == 0.1
    monkeypatch.setenv("REPRO_DENSE_THRESHOLD", "0.25")
    assert compose.resolve_dense_threshold() == 0.25
    with compose.dense_threshold_scope(0.4):
        assert compose.resolve_dense_threshold() == 0.4
        # explicit beats the scope, which beats the env
        assert compose.resolve_dense_threshold(0.05) == 0.05
    assert compose.resolve_dense_threshold() == 0.25


# -- Plan objects --------------------------------------------------------

def test_manual_plan_records_explicit_sources():
    plan = manual_plan(mode="chunked", chunk_size=8, route_impl="sort",
                       explicit={"mode": "chunked", "chunk_size": 8,
                                 "route_impl": "sort"})
    assert plan.source == "manual"
    assert plan.key()[:2] == ("chunked", 8)
    assert plan.decision("route_impl").source == "explicit"
    assert plan.decision("use_kernel").source == "default"


def test_plan_json_round_trip_auto():
    _, _, pg, prog = _problem()
    plan = Planner(calibrate=False).plan(prog, pg)
    assert plan.source == "auto" and plan.fingerprint is not None
    rt = Plan.from_json(json.dumps(plan.to_json()))
    assert rt.knobs() == plan.knobs()
    assert rt.key() == plan.key()
    assert rt.fingerprint == plan.fingerprint
    assert [d.knob for d in rt.decisions] == [d.knob for d in plan.decisions]
    assert rt.decision("route_impl").source == \
        plan.decision("route_impl").source


def test_runresult_plan_stamped_and_round_trips():
    _, _, pg, prog = _problem()
    res = Engine().run(prog, pg)
    assert res.plan is not None and res.plan.source == "manual"
    rt = Plan.from_json(json.dumps(res.plan.to_json()))
    assert rt.knobs() == res.plan.knobs()


def test_planner_explain_lists_every_knob():
    _, _, pg, prog = _problem()
    text = Planner(calibrate=False).plan(prog, pg).explain()
    for knob in ("mode", "chunk_size", "use_kernel", "route_impl",
                 "route_batch", "dense_threshold"):
        assert knob in text


# -- Engine plan policies ------------------------------------------------

def test_engine_rejects_unknown_plan():
    with pytest.raises(ValueError, match="unknown plan"):
        Engine(plan="always")


def test_explicit_knob_wins_under_auto():
    _, _, pg, prog = _problem()
    eng = Engine(plan="auto", route_impl="sort")
    plan = eng.resolve_plan(prog, pg)
    assert plan.route_impl == "sort"
    assert plan.decision("route_impl").source == "explicit"
    # the un-set knobs are still the planner's
    assert plan.decision("use_kernel").source == "planner"


def test_given_plan_is_used_and_explicit_still_wins():
    given = Plan(mode="chunked", chunk_size=8, route_impl="sort")
    _, _, pg, prog = _problem()
    assert Engine(plan=given).resolve_plan(prog, pg).key() == given.key()
    over = Engine(plan=given, route_impl="bucket").resolve_plan(prog, pg)
    assert over.route_impl == "bucket" and over.mode == "chunked"


def test_auto_plan_memoized_per_fingerprint():
    _, _, pg, prog = _problem()
    eng = Engine(plan="auto")
    assert eng.resolve_plan(prog, pg) is eng.resolve_plan(prog, pg)


def test_planner_does_not_touch_engine_cache_or_stats():
    _, _, pg, prog = _problem()
    eng = Engine(plan="auto")
    eng.resolve_plan(prog, pg)  # runs calibration probes
    assert eng.stats() == {"compiles": 0, "cache_hits": 0,
                           "cached_executables": 0, "runs": 0}


def test_planned_and_hand_set_runs_share_one_executable():
    """A planner choice and the identical hand-set choice have the same
    cache key: the second run is a hit, not a recompile."""
    _, _, pg, prog = _problem()
    eng = Engine(plan="auto")
    r1 = eng.run(prog, pg)
    # replay through the same engine with plan pre-resolved: cache hit
    r2 = eng.run(prog, pg)
    assert r1.plan.key() == r2.plan.key()
    assert eng.compiles == 1 and eng.cache_hits == 1


# -- bit-identity: planned == hand-set ----------------------------------

def _assert_bit_identical(key, mode):
    spec, _, pg, prog = _problem(key)
    auto = Engine(plan="auto", mode=mode)
    res_a = auto.run(prog, pg)
    plan = res_a.plan
    assert plan.source == "auto"
    hand = Engine(mode=mode, chunk_size=plan.chunk_size,
                  use_kernel=plan.use_kernel, route_impl=plan.route_impl,
                  route_batch=plan.route_batch,
                  dense_threshold=plan.dense_threshold)
    res_h = hand.run(prog, pg)
    assert res_h.plan.source == "manual"
    np.testing.assert_array_equal(np.asarray(res_a.output),
                                  np.asarray(res_h.output))
    assert res_a.steps == res_h.steps
    assert res_a.bytes_by_channel == res_h.bytes_by_channel


def test_auto_bit_identical_fused_smoke():
    _assert_bit_identical("sssp:basic", "fused")


@pytest.mark.slow
@pytest.mark.parametrize("mode", ("fused", "chunked"))
@pytest.mark.parametrize("key", ("wcc:switch", "sssp:basic",
                                 "pagerank:scatter"))
def test_auto_bit_identical_sweep(key, mode):
    _assert_bit_identical(key, mode)


# -- cross-process determinism ------------------------------------------

_SNIPPET = """
import json
from repro.algorithms import REGISTRY
from repro.graph import pgraph
from repro.plan import Planner

spec = REGISTRY["sssp:basic"]
graph = spec.make_graph(8, 0)
pg = pgraph.partition_graph(graph, 4, "random", build=spec.build)
prog = spec.factory(**spec.inputs(graph, 0))
plan = Planner().plan(prog, pg)
print(json.dumps({"knobs": plan.knobs(),
                  "fp": plan.fingerprint.cache_key()}, sort_keys=True))
"""


@pytest.mark.slow
def test_plan_deterministic_across_processes(tmp_path):
    """Same problem, two fresh interpreters: the first populates the
    calibration cache (cold), the second reads it (warm) — both must
    produce the identical plan."""
    def run_once(cache_dir):
        import os
        env = {**os.environ, "REPRO_PLAN_CACHE": str(cache_dir)}
        out = subprocess.run([sys.executable, "-c", _SNIPPET],
                             capture_output=True, text=True, env=env,
                             check=True)
        return json.loads(out.stdout.strip().splitlines()[-1])

    cache = tmp_path / "cache"
    cold = run_once(cache)
    assert cache.exists() and list(cache.glob("*.json"))
    warm = run_once(cache)
    assert cold == warm, f"cold={cold} warm={warm}"
