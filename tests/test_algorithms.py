"""All paper algorithms vs host oracles, every channel variant."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.graph import generators as gen
from repro.graph import oracles, pgraph
from repro.algorithms import (msf, pagerank, pointer_jumping, scc, sssp, sv,
                              wcc)


def canon(x):
    first = {}
    return np.array([first.setdefault(v, i) for i, v in enumerate(x)])


@pytest.fixture(scope="module")
def rmat_directed():
    return gen.rmat(9, edge_factor=4, seed=2)


@pytest.fixture(scope="module")
def rmat_sym(rmat_directed):
    return rmat_directed.symmetrized()


@pytest.fixture(scope="module")
def pg_sym(rmat_sym):
    return pgraph.partition_graph(
        rmat_sym, 4, "random",
        build=("scatter_out", "prop_out", "raw_out"),
    )


@pytest.mark.parametrize("variant", ["basic", "scatter"])
def test_pagerank(rmat_directed, variant):
    pg = pgraph.partition_graph(rmat_directed, 4, "random",
                                build=("scatter_out", "raw_out"))
    pr, res = pagerank.run(pg, iters=15, variant=variant)
    want = oracles.pagerank_oracle(rmat_directed, iters=15)
    np.testing.assert_allclose(pr, want, rtol=1e-4, atol=1e-7)
    assert res.steps == 15


@pytest.mark.slow
def test_pagerank_scatter_fewer_bytes(rmat_directed):
    pg = pgraph.partition_graph(rmat_directed, 4, "random",
                                build=("scatter_out", "raw_out"))
    _, res_b = pagerank.run(pg, iters=5, variant="basic")
    _, res_s = pagerank.run(pg, iters=5, variant="scatter")
    assert res_s.total_bytes < res_b.total_bytes  # ids removed from the wire


@pytest.mark.parametrize("variant", ["basic", "reqresp"])
@pytest.mark.parametrize("shape", ["chain", "tree"])
def test_pointer_jumping(variant, shape):
    n = 600
    par = (gen.parent_chain(n, seed=1) if shape == "chain"
           else gen.random_tree_parents(n, seed=1))
    empty = gen.EdgeList(n, np.zeros((0, 2), np.int64), None, True, "pj")
    pg = pgraph.partition_graph(empty, 4, "random", build=())
    roots_new, res = pointer_jumping.run(pg, par, variant=variant)
    # oracle: root of each vertex via repeated jumping in numpy
    p = par.copy()
    for _ in range(n):
        nxt = p[p]
        if (nxt == p).all():
            break
        p = nxt
    new = pg.new_of_old.arr
    np.testing.assert_array_equal(roots_new, new[p])
    assert res.halted and res.steps <= int(np.ceil(np.log2(n))) + 2


def test_reqresp_fewer_bytes_on_tree():
    n = 600
    par = gen.random_tree_parents(n, seed=1)
    empty = gen.EdgeList(n, np.zeros((0, 2), np.int64), None, True, "pj")
    pg = pgraph.partition_graph(empty, 4, "random", build=())
    _, res_b = pointer_jumping.run(pg, par, variant="basic")
    _, res_r = pointer_jumping.run(pg, par, variant="reqresp")
    assert res_r.total_bytes < res_b.total_bytes


@pytest.mark.parametrize("variant", ["basic", "prop"])
def test_wcc(rmat_sym, pg_sym, variant):
    lab, res = wcc.run(pg_sym, variant=variant)
    truth = gen.components_ground_truth(rmat_sym)
    np.testing.assert_array_equal(canon(lab), canon(truth))


def test_wcc_prop_fewer_global_rounds():
    g = gen.grid2d(20)
    pg = pgraph.partition_graph(g, 4, "bfs",
                                build=("prop_out", "raw_out"))
    _, res_b = wcc.run(pg, variant="basic")
    lab, res_p = wcc.run(pg, variant="prop")
    rounds = int(np.asarray(res_p.state["info"])[:, 0].max())
    assert rounds < res_b.steps  # block-centric effect
    truth = gen.components_ground_truth(g)
    np.testing.assert_array_equal(canon(lab), canon(truth))


@pytest.mark.parametrize("variant", ["basic", "reqresp", "scatter", "both"])
@pytest.mark.slow
def test_sv(rmat_sym, pg_sym, variant):
    lab, res = sv.run(pg_sym, variant=variant)
    truth = gen.components_ground_truth(rmat_sym)
    np.testing.assert_array_equal(canon(lab), canon(truth))
    assert res.halted


@pytest.mark.slow
def test_sv_composition_fewest_bytes(pg_sym):
    totals = {}
    for variant in ("basic", "reqresp", "scatter", "both"):
        _, res = sv.run(pg_sym, variant=variant)
        totals[variant] = res.total_bytes
    assert totals["both"] < totals["reqresp"] < totals["basic"]
    assert totals["both"] < totals["scatter"] < totals["basic"]


@pytest.mark.parametrize("variant", ["basic", "prop"])
def test_sssp(variant):
    g = gen.rmat(9, edge_factor=4, seed=5, weighted=True)
    pg = pgraph.partition_graph(g, 4, "random", build=("prop_out", "raw_out"))
    want = oracles.sssp_oracle(g, source=0)
    dist, res = sssp.run(pg, 0, variant=variant)
    finite = ~np.isinf(want)
    np.testing.assert_allclose(dist[finite], want[finite], rtol=1e-5)
    assert np.isinf(dist[~finite]).all()


@pytest.mark.parametrize("variant", ["prop", "basic"])
@pytest.mark.slow
def test_scc(variant):
    g = gen.rmat(8, edge_factor=3, seed=7)
    pg = pgraph.partition_graph(
        g, 4, "random",
        build=("scatter_out", "scatter_in", "prop_out", "prop_in",
               "raw_out", "raw_in"),
    )
    want = oracles.scc_oracle(g)
    lab, res = scc.run(pg, variant=variant)
    np.testing.assert_array_equal(canon(lab), canon(want))


@pytest.mark.parametrize("variant", ["channels", "monolithic"])
@pytest.mark.slow
def test_msf(variant):
    g = gen.rmat(8, edge_factor=4, seed=9, weighted=True).symmetrized()
    pg = pgraph.partition_graph(g, 4, "random", build=("raw_out",))
    want_w = oracles.msf_weight_oracle(g)
    out, res = msf.run(pg, variant=variant)
    assert abs(out["weight"] - want_w) < 1e-2
    truth = gen.components_ground_truth(g)
    assert out["edges"] == g.n - len(set(truth.tolist()))


@pytest.mark.slow
def test_msf_typed_channels_fewer_bytes():
    g = gen.rmat(8, edge_factor=4, seed=9, weighted=True).symmetrized()
    pg = pgraph.partition_graph(g, 4, "random", build=("raw_out",))
    _, res_t = msf.run(pg, variant="channels")
    _, res_m = msf.run(pg, variant="monolithic")
    # the paper reports 23-82% message reduction for heterogeneous-message
    # algorithms; ours is at least 50% here
    assert res_t.total_bytes < 0.5 * res_m.total_bytes


def test_partitioners_all_give_correct_wcc(rmat_sym):
    truth = gen.components_ground_truth(rmat_sym)
    for part in ("block", "random", "bfs"):
        pg = pgraph.partition_graph(rmat_sym, 3, part, build=("prop_out",))
        lab, _ = wcc.run(pg, variant="prop")
        np.testing.assert_array_equal(canon(lab), canon(truth))
