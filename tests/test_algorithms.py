"""Registry-driven algorithm sweep + the paper's channel-property checks.

The sweep is parametrized straight off ``repro.algorithms.REGISTRY``:
every registered program×variant runs at small scale in all three
execution modes, is verified against its host oracle
(``repro/graph/oracles.py`` via each spec's ``check``), and is compared
bit-for-bit against the backward-compatible module ``run()`` wrapper.
Adding a variant to the registry adds it to the sweep — no test edits.

Non-slow subset: fused mode on the cheap algorithms (the smoke tier);
host/chunked modes and the heavy algorithms (sv/msf/scc) are @slow.
"""
import functools

import numpy as np
import pytest

from repro.algorithms import REGISTRY, get_program
from repro.graph import generators as gen, pgraph
from repro.pregel.engine import Engine

SEED = 0
W = 4
CHUNK = 3  # forces several dispatches in chunked mode
MODES = ("fused", "host", "chunked")
HEAVY = {"sv", "msf", "scc"}  # slow even in fused mode


def canon(x):
    first = {}
    return np.array([first.setdefault(v, i) for i, v in enumerate(x)])


@functools.lru_cache(maxsize=None)
def problem(key):
    """(graph, pg, inputs, program) for a registry key — cached so the
    three mode runs share one partition and one program instance."""
    spec = REGISTRY[key]
    graph = spec.make_graph(spec.test_scale, SEED)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    inputs = spec.inputs(graph, SEED)
    return graph, pg, inputs, spec.factory(**inputs)


def sweep_params():
    for key in sorted(REGISTRY):
        spec = REGISTRY[key]
        for mode in MODES:
            slow = mode != "fused" or spec.algorithm in HEAVY
            yield pytest.param(key, mode,
                               marks=[pytest.mark.slow] if slow else [],
                               id=f"{key}-{mode}")


def assert_same_output(a, b):
    if isinstance(a, dict):
        assert a.keys() == b.keys()
        for k in a:
            assert_same_output(a[k], b[k])
    elif isinstance(a, (int, float)):
        assert a == b
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("key,mode", sweep_params())
def test_registry_sweep(key, mode):
    spec = REGISTRY[key]
    graph, pg, inputs, prog = problem(key)
    res = Engine(mode=mode, chunk_size=CHUNK).run(prog, pg)
    # 1. the program's answer matches the host oracle
    spec.check(graph, pg, res, inputs)
    # 2. the registry-driven run is bit-identical to the legacy wrapper
    out_legacy, res_legacy = spec.legacy(pg, inputs, mode, CHUNK)
    assert_same_output(res.output, out_legacy)
    assert (res.steps, res.halted) == (res_legacy.steps, res_legacy.halted)
    assert res.bytes_by_channel == res_legacy.bytes_by_channel
    assert res.msgs_by_channel == res_legacy.msgs_by_channel


# ---------------------------------------------------------------------------
# paper channel properties (Tables IV-VII effects), via the registry API
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_pagerank_scatter_fewer_bytes():
    g = gen.rmat(9, edge_factor=4, seed=2)
    pg = pgraph.partition_graph(g, 4, "random",
                                build=("scatter_out", "raw_out"))
    eng = Engine()
    res_b = eng.run(get_program("pagerank:basic", iters=5), pg)
    res_s = eng.run(get_program("pagerank:scatter", iters=5), pg)
    assert res_s.total_bytes < res_b.total_bytes  # ids removed from the wire


def test_reqresp_fewer_bytes_on_tree():
    spec = REGISTRY["pj:reqresp"]
    graph, pg, inputs, prog_r = problem("pj:reqresp")
    prog_b = REGISTRY["pj:basic"].factory(**inputs)
    eng = Engine()
    res_r = eng.run(prog_r, pg)
    res_b = eng.run(prog_b, pg)
    assert res_r.total_bytes < res_b.total_bytes


def test_wcc_prop_fewer_global_rounds():
    g = gen.grid2d(20)
    pg = pgraph.partition_graph(g, 4, "bfs",
                                build=("prop_out", "raw_out"))
    eng = Engine()
    res_b = eng.run(get_program("wcc:basic"), pg)
    res_p = eng.run(get_program("wcc:prop"), pg)
    rounds = int(np.asarray(res_p.state["info"])[:, 0].max())
    assert rounds < res_b.steps  # block-centric effect
    truth = gen.components_ground_truth(g)
    np.testing.assert_array_equal(canon(res_p.output), canon(truth))


@pytest.mark.slow
def test_sv_composition_fewest_bytes():
    _, pg, _, _ = problem("sv:basic")
    eng = Engine()
    totals = {v: eng.run(get_program(f"sv:{v}"), pg).total_bytes
              for v in ("basic", "reqresp", "scatter", "both")}
    assert totals["both"] < totals["reqresp"] < totals["basic"]
    assert totals["both"] < totals["scatter"] < totals["basic"]


@pytest.mark.slow
def test_msf_typed_channels_fewer_bytes():
    _, pg, _, _ = problem("msf:channels")
    eng = Engine()
    res_t = eng.run(get_program("msf:channels"), pg)
    res_m = eng.run(get_program("msf:monolithic"), pg)
    # the paper reports 23-82% message reduction for heterogeneous-message
    # algorithms; ours is at least 50% here
    assert res_t.total_bytes < 0.5 * res_m.total_bytes


def test_partitioners_all_give_correct_wcc():
    g = gen.rmat(9, edge_factor=4, seed=2).symmetrized()
    truth = gen.components_ground_truth(g)
    prog = get_program("wcc:prop")
    eng = Engine()
    for part in ("block", "random", "bfs"):
        pg = pgraph.partition_graph(g, 3, part, build=("prop_out",))
        res = eng.run(prog, pg)
        np.testing.assert_array_equal(canon(res.output), canon(truth))
