"""Composition-layer tests (repro.core.compose, paper §V).

Covers: composed S-V parity (bit-identical final states vs. the
unoptimized S-V, across all three execution modes), namespaced traffic
attribution (component stats sum to the run totals and match the
individual channels run standalone), fused_exchange equivalence to
separate collectives, the density switch, and composed-registry
declaration through ``run_supersteps(channels=<stack>)``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import sv, wcc
from repro.core import compose
from repro.core import scatter_combine as sc
from repro.core.channel import ChannelContext
from repro.graph import generators as gen, pgraph
from repro.pregel import runtime

MODES = ("host", "fused", "chunked")


@pytest.fixture(scope="module")
def pg_small():
    g = gen.rmat(8, edge_factor=4, seed=11).symmetrized()
    return pgraph.partition_graph(
        g, 4, "random", build=("scatter_out", "scatter_in", "prop_out",
                               "raw_out")
    )


# ---------------------------------------------------------------------------
# composed S-V
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_composed_sv_parity_all_modes(pg_small):
    """Composed S-V == unoptimized S-V final states, in every mode."""
    lab_basic, _ = sv.run(pg_small, variant="basic")
    for mode in MODES:
        lab, res = sv.run(pg_small, variant="composed", mode=mode,
                          chunk_size=3)
        np.testing.assert_array_equal(lab_basic, lab)
        assert res.halted


@pytest.mark.slow
def test_composed_sv_mode_parity_traffic(pg_small):
    """Namespaced stats are themselves mode-invariant (bit-identical)."""
    results = {m: sv.run(pg_small, variant="composed", mode=m, chunk_size=3)[1]
               for m in MODES}
    ref = results["host"]
    for mode in ("fused", "chunked"):
        r = results[mode]
        assert r.steps == ref.steps
        assert r.bytes_by_channel == ref.bytes_by_channel
        assert r.msgs_by_channel == ref.msgs_by_channel


def test_composed_sv_namespaced_attribution(pg_small):
    """Every stat key lives under sv/, per-component sums equal the run
    totals, and the prefix helpers agree with manual slicing."""
    _, res = sv.run(pg_small, variant="composed")
    chan = sv.composed_channels()
    assert tuple(sorted(res.bytes_by_channel)) == chan.channel_names()
    assert all(k.startswith("sv/") for k in res.bytes_by_channel)
    grouped = compose.group_stats(res.bytes_by_channel)
    assert set(grouped) == {"sv"}
    assert grouped["sv"] == res.total_bytes
    per_component = sum(
        res.bytes_under(f"sv/{key}") for key in chan.components
    )
    assert per_component == res.total_bytes
    # request-respond contributes both of its wires
    assert res.bytes_under("sv/pointer") == (
        res.bytes_by_channel["sv/pointer/request"]
        + res.bytes_by_channel["sv/pointer/respond"]
    )


@pytest.mark.slow
def test_composed_sv_beats_unoptimized(pg_small):
    """The acceptance property: composed <= unoptimized on global rounds
    and strictly less traffic."""
    _, res_basic = sv.run(pg_small, variant="basic")
    _, res_comp = sv.run(pg_small, variant="composed")
    assert res_comp.steps <= res_basic.steps
    assert res_comp.total_bytes < res_basic.total_bytes


def test_stacked_declaration_mismatch_raises(pg_small):
    """A composed declaration that misses a traced channel is an error —
    raised lazily by ChannelContext.add_traffic when the step is traced
    for compilation (declared programs skip the eval_shape dry trace)."""
    chan = sv.composed_channels()
    wrong = compose.stacked("sv", pointer=chan.components["pointer"])
    with pytest.raises(KeyError, match="not in the registry"):
        runtime.run_supersteps(
            pg_small, sv._composed_step(chan),
            {"D": pg_small.global_ids().astype(jnp.int32)},
            max_steps=2, channels=wrong,
        )


# ---------------------------------------------------------------------------
# fused_exchange
# ---------------------------------------------------------------------------


def test_fused_exchange_matches_separate_collectives(pg_small):
    """Merging two scatter-combines into one collective round changes
    neither the results nor the per-channel accounting."""
    vals = jnp.where(pg_small.v_mask, pg_small.deg_out, 0).astype(jnp.float32)

    def step_fused(ctx, gs, state, i):
        a, b = compose.fused_exchange(ctx, [
            sc.plan_broadcast_combine(ctx, gs.scatter_out, state["x"], "sum",
                                      name="a"),
            sc.plan_broadcast_combine(ctx, gs.scatter_in, state["x"], "min",
                                      name="b"),
        ])
        return {"x": state["x"], "a": a, "b": b}, True

    def step_separate(ctx, gs, state, i):
        a = sc.broadcast_combine(ctx, gs.scatter_out, state["x"], "sum",
                                 name="a")
        b = sc.broadcast_combine(ctx, gs.scatter_in, state["x"], "min",
                                 name="b")
        return {"x": state["x"], "a": a, "b": b}, True

    z = jnp.zeros_like(vals)
    state0 = {"x": vals, "a": z, "b": z}
    r_f = runtime.run_supersteps(pg_small, step_fused, state0, max_steps=1)
    r_s = runtime.run_supersteps(pg_small, step_separate, state0, max_steps=1)
    np.testing.assert_array_equal(np.asarray(r_f.state["a"]),
                                  np.asarray(r_s.state["a"]))
    np.testing.assert_array_equal(np.asarray(r_f.state["b"]),
                                  np.asarray(r_s.state["b"]))
    assert r_f.bytes_by_channel == r_s.bytes_by_channel
    assert r_f.msgs_by_channel == r_s.msgs_by_channel


def test_fused_exchange_mixed_dtypes():
    """Leaves group by dtype: one collective per dtype, results exact."""
    W = 4

    def shard(x_i, x_f):
        ctx = ChannelContext("w", W, 4)
        (ri, rf) = compose.fused_exchange(ctx, [
            compose.PlannedExchange("ints", {"v": x_i}, lambda r: r["v"],
                                    0, 0),
            compose.PlannedExchange("floats", {"v": x_f}, lambda r: r["v"],
                                    0, 0),
        ])
        return ri, rf

    rng = np.random.default_rng(0)
    x_i = rng.integers(0, 100, (W, W, 3)).astype(np.int32)
    x_f = rng.normal(size=(W, W, 2, 2)).astype(np.float32)
    ri, rf = jax.vmap(shard, axis_name="w")(jnp.asarray(x_i),
                                            jnp.asarray(x_f))
    # all_to_all semantics: out[p][q] = in[q][p]
    np.testing.assert_array_equal(np.asarray(ri), x_i.swapaxes(0, 1))
    np.testing.assert_array_equal(np.asarray(rf), x_f.swapaxes(0, 1))


# ---------------------------------------------------------------------------
# switch_by_density
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_wcc_switch_parity(pg_small, mode):
    """The density switch never changes labels, steps, or halting."""
    lab_b, res_b = wcc.run(pg_small, variant="basic")
    lab_s, res_s = wcc.run(pg_small, variant="switch", mode=mode,
                           chunk_size=3)
    np.testing.assert_array_equal(lab_b, lab_s)
    assert (res_s.steps, res_s.halted) == (res_b.steps, res_b.halted)


def test_switch_accounts_only_chosen_branch(pg_small):
    """Forced thresholds: the unchosen branch's traffic is masked to 0."""
    _, res_dense = wcc.run(pg_small, variant="switch", dense_threshold=0.0)
    assert res_dense.bytes_under("wcc/dense") > 0
    assert res_dense.bytes_under("wcc/sparse") == 0
    _, res_sparse = wcc.run(pg_small, variant="switch", dense_threshold=1.1)
    assert res_sparse.bytes_under("wcc/sparse") > 0
    assert res_sparse.bytes_under("wcc/dense") == 0
    # both branches' keys exist in every run (registry contract)
    for res in (res_dense, res_sparse):
        assert "wcc/dense/scatter_combine" in res.bytes_by_channel
        assert "wcc/sparse/combined_message" in res.bytes_by_channel


def test_switch_dense_between_sparse_totals(pg_small):
    """A mid threshold starts dense and finishes sparse."""
    _, res = wcc.run(pg_small, variant="switch", dense_threshold=0.5)
    assert res.bytes_under("wcc/dense") > 0
    assert res.bytes_under("wcc/sparse") > 0


# ---------------------------------------------------------------------------
# scoped accounting primitives
# ---------------------------------------------------------------------------


def test_scoped_merge_and_select():
    ctx = ChannelContext("w", 2, 4)
    with compose.scoped(ctx, "outer") as sub:
        sub.add_traffic("inner", 10, 1)
    with compose.scoped(ctx, "masked", select=0) as sub:
        sub.add_traffic("inner", 10, 1)
    assert int(ctx.stats_bytes["outer/inner"]) == 10
    assert int(ctx.stats_bytes["masked/inner"]) == 0
    assert int(ctx.stats_msgs["masked/inner"]) == 0


def test_channel_names_of_mixed_sequence():
    chan = sv.composed_channels()
    names = compose.channel_names_of([chan, "extra"])
    assert "extra" in names
    assert set(chan.channel_names()) <= set(names)
    # a bare string is a single declaration, not a char sequence
    assert compose.channel_names_of("scatter_combine") == ("scatter_combine",)
