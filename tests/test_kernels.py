"""Pallas segment_combine kernel vs the pure-jnp oracle: shape/dtype
sweeps + hypothesis property tests."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.core import combiners as cb
from repro.kernels import ops, ref

COMBINERS = ["sum", "min", "max"]


@pytest.mark.parametrize("combiner", COMBINERS)
@pytest.mark.parametrize(
    "e,n,d", [(64, 16, 1), (1000, 300, 1), (513, 128, 3), (2048, 777, 5),
              (4096, 64, 8), (100, 1000, 2)]
)
def test_kernel_matches_ref_f32(e, n, d, combiner):
    rng = np.random.default_rng(e + n + d)
    seg = np.sort(rng.integers(0, n, e)).astype(np.int32)
    vals = rng.normal(size=(e, d)).astype(np.float32)
    want = ref.segment_combine_ref(jnp.array(vals), jnp.array(seg), n, combiner)
    got = ops.segment_combine(
        jnp.array(vals), jnp.array(seg), n, combiner,
        use_kernel=True, assume_sorted=True,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("combiner", ["min", "max"])
def test_kernel_matches_ref_int32(combiner):
    rng = np.random.default_rng(0)
    seg = np.sort(rng.integers(0, 50, 400)).astype(np.int32)
    vals = rng.integers(-1000, 1000, (400, 2)).astype(np.int32)
    want = ref.segment_combine_ref(jnp.array(vals), jnp.array(seg), 50, combiner)
    got = ops.segment_combine(jnp.array(vals), jnp.array(seg), 50, combiner,
                              use_kernel=True, assume_sorted=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_unsorted_input_sorts():
    rng = np.random.default_rng(1)
    seg = rng.integers(0, 37, 300).astype(np.int32)
    vals = rng.normal(size=(300, 2)).astype(np.float32)
    want = ref.segment_combine_ref(jnp.array(vals), jnp.array(seg), 37, "sum")
    got = ops.segment_combine(jnp.array(vals), jnp.array(seg), 37, "sum",
                              use_kernel=True, assume_sorted=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_kernel_out_of_range_dropped():
    seg = np.array([0, 0, 1, 5, 9, 9], np.int32)  # 5, 9 out of range for n=4
    vals = np.ones((6, 1), np.float32)
    got = ops.segment_combine(jnp.array(vals), jnp.array(seg), 4, "sum",
                              use_kernel=True, assume_sorted=True)
    np.testing.assert_allclose(np.asarray(got)[:, 0], [2, 1, 0, 0])


def test_kernel_custom_block_sizes():
    rng = np.random.default_rng(2)
    seg = np.sort(rng.integers(0, 100, 1500)).astype(np.int32)
    vals = rng.normal(size=(1500, 2)).astype(np.float32)
    want = ref.segment_combine_ref(jnp.array(vals), jnp.array(seg), 100, "sum")
    for br, be in [(8, 64), (32, 128), (256, 1024)]:
        got = ops.segment_combine(jnp.array(vals), jnp.array(seg), 100, "sum",
                                  use_kernel=True, assume_sorted=True,
                                  block_rows=br, block_edges=be)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    e=st.integers(1, 600),
    n=st.integers(1, 200),
    combiner=st.sampled_from(COMBINERS),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_property(e, n, combiner, seed):
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, n, e)).astype(np.int32)
    vals = rng.normal(size=(e, 1)).astype(np.float32)
    want = ref.segment_combine_ref(jnp.array(vals), jnp.array(seg), n, combiner)
    got = ops.segment_combine(jnp.array(vals), jnp.array(seg), n, combiner,
                              use_kernel=True, assume_sorted=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 50))
def test_min_by_first_combiner_property(seed, n):
    """min_by_first == argmin by key, payload carried along."""
    rng = np.random.default_rng(seed)
    e = 300
    seg = rng.integers(0, n, e).astype(np.int32)
    keys = rng.permutation(e).astype(np.float32)  # unique keys
    payload = rng.normal(size=(e, 2)).astype(np.float32)
    vals = np.concatenate([keys[:, None], payload], axis=1)
    got = cb.MIN_BY_FIRST.segment_reduce(jnp.array(vals), jnp.array(seg), n)
    got = np.asarray(got)
    for s in range(n):
        sel = seg == s
        if not sel.any():
            assert np.isinf(got[s, 0])
        else:
            i = np.flatnonzero(sel)[np.argmin(keys[sel])]
            np.testing.assert_allclose(got[s], vals[i], rtol=1e-6)
