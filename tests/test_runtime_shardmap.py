"""shard_map backend parity: the deployment path must be bit-identical
to the vmap backend (states, outputs, steps, per-channel traffic) on a
real multi-device mesh.

The worker axis is a *real* 4-device CPU mesh, forced via
``--xla_force_host_platform_device_count=4`` — which must be set before
jax initializes, so the comparison runs in a subprocess (this test
process has long since touched jax). One subprocess covers every
program (wcc, sv:composed, sssp) plus a batched run_batch parity check;
subprocess spawn + compiles make it a @slow test.
"""
import os
import pathlib
import subprocess
import sys

import pytest

KEYS = ("wcc:basic", "sv:composed", "sssp:basic")

SCRIPT = r'''
import numpy as np
import jax

assert jax.device_count() == 4, f"forced CPU devices missing: {jax.devices()}"

from repro.algorithms import REGISTRY
from repro.graph import pgraph
from repro.pregel.engine import Engine

W = 4
mesh = jax.make_mesh((W,), ("workers",))

for key in %(keys)r:
    spec = REGISTRY[key]
    graph = spec.make_graph(spec.test_scale, 0)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    inputs = spec.inputs(graph, 0)
    prog = spec.factory(**inputs)
    r_v = Engine(backend="vmap").run(prog, pg)
    r_s = Engine(backend="shard_map", mesh=mesh).run(prog, pg)
    assert (r_s.steps, r_s.halted) == (r_v.steps, r_v.halted), key
    assert r_s.bytes_by_channel == r_v.bytes_by_channel, (
        key, r_s.bytes_by_channel, r_v.bytes_by_channel)
    assert r_s.msgs_by_channel == r_v.msgs_by_channel, key
    for lv, ls in zip(jax.tree_util.tree_leaves(r_v.state),
                      jax.tree_util.tree_leaves(r_s.state)):
        np.testing.assert_array_equal(np.asarray(lv), np.asarray(ls))
    np.testing.assert_array_equal(np.asarray(r_v.output),
                                  np.asarray(r_s.output))
    print(key, "parity ok:", r_s.steps, "steps,",
          sum(r_s.bytes_by_channel.values()), "bytes")

# the batched query plane rides the same mapped step — spot-check it too
spec = REGISTRY["sssp:basic"]
graph = spec.make_graph(spec.test_scale, 0)
pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
prog = spec.factory(**spec.inputs(graph, 0))
queries = spec.queries(graph, 0, 3)
rb_v = Engine(backend="vmap").run_batch(prog, pg, queries)
rb_s = Engine(backend="shard_map", mesh=mesh).run_batch(prog, pg, queries)
assert rb_s.query_steps.tolist() == rb_v.query_steps.tolist()
for qi in range(len(queries)):
    np.testing.assert_array_equal(np.asarray(rb_v.outputs[qi]),
                                  np.asarray(rb_s.outputs[qi]))
    assert rb_s.query_bytes(qi) == rb_v.query_bytes(qi), qi
print("run_batch parity ok:", rb_s.query_steps.tolist(), "steps")

# the serving substrate (per-lane ages, chunk-boundary lane swap) must
# serve bit-identically on the mesh too — same queries, forced refills
from repro.pregel.serve import QueryQueue

serve_v = Engine(backend="vmap", mode="chunked", chunk_size=2).serve(
    prog, pg, QueryQueue.from_queries(queries), num_lanes=2)
serve_s = Engine(backend="shard_map", mesh=mesh, mode="chunked",
                 chunk_size=2).serve(
    prog, pg, QueryQueue.from_queries(queries), num_lanes=2)
assert len(serve_s.records) == len(queries)
for rv, rs in zip(serve_v.records, serve_s.records):
    assert (rs.qid, rs.lane, rs.admitted, rs.finished, rs.steps) == \
        (rv.qid, rv.lane, rv.admitted, rv.finished, rv.steps), rs.qid
    np.testing.assert_array_equal(np.asarray(rv.output),
                                  np.asarray(rs.output))
    assert rs.bytes_by_channel == rv.bytes_by_channel, rs.qid
    assert rs.msgs_by_channel == rv.msgs_by_channel, rs.qid
# and to solo runs on the mesh itself
eng_s = Engine(backend="shard_map", mesh=mesh)
for rec in serve_s.records:
    solo = eng_s.run_batch(prog, pg, [rec.query])
    np.testing.assert_array_equal(np.asarray(rec.output),
                                  np.asarray(solo.outputs[0]))
    assert rec.steps == int(solo.query_steps[0]), rec.qid
print("serve parity ok:", [r.steps for r in serve_s.records], "steps")

print("SHARDMAP-PARITY-OK")
''' % {"keys": KEYS}


@pytest.mark.slow
def test_shardmap_backend_bit_identical_to_vmap():
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (str(root / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=str(root))
    assert proc.returncode == 0, f"\n--- stdout:\n{proc.stdout}" \
                                 f"\n--- stderr:\n{proc.stderr}"
    assert "SHARDMAP-PARITY-OK" in proc.stdout
