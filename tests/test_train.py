"""Training substrate: grad-accum equivalence, checkpoint round-trip +
elastic resharding, compression error feedback, serving generation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.distributed import compression
from repro.distributed.fault_tolerance import StragglerMonitor, TrainSupervisor
from repro.models import model as M, params as Pm
from repro.models.config import ModelConfig
from repro.serve import decode as serve
from repro.train import checkpoint as ckpt
from repro.train import data as data_lib
from repro.train import train_step as ts
from repro.train.optimizer import AdamW

TINY = ModelConfig("tiny", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                   d_ff=64, vocab=61, dtype="float32")


@pytest.mark.slow
def test_grad_accumulation_equivalence():
    """microbatches=4 must give the same update as microbatches=1."""
    opt = AdamW(lr=1e-3, grad_clip=0)
    state = ts.init_train_state(TINY, opt, jax.random.PRNGKey(0))
    pipe = data_lib.SyntheticLM(TINY, seq_len=16, global_batch=8)
    batch = pipe.batch_at(0)
    s1, m1 = jax.jit(ts.make_train_step(TINY, opt, microbatches=1))(state, batch)
    s4, m4 = jax.jit(ts.make_train_step(TINY, opt, microbatches=4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


@pytest.mark.slow
def test_loss_decreases_100_steps():
    opt = AdamW(lr=3e-3, warmup_steps=10)
    state = ts.init_train_state(TINY, opt, jax.random.PRNGKey(0))
    step = jax.jit(ts.make_train_step(TINY, opt))
    pipe = data_lib.SyntheticLM(TINY, seq_len=32, global_batch=8)
    losses = []
    for i in range(100):
        state, m = step(state, pipe.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2
    assert np.all(np.isfinite(losses))


def test_checkpoint_roundtrip(tmp_path):
    opt = AdamW()
    state = ts.init_train_state(TINY, opt, jax.random.PRNGKey(1))
    ckpt.save(str(tmp_path), 7, state)
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored = ckpt.restore(str(tmp_path), state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_elastic_reshard(tmp_path):
    """Checkpoint saved unsharded restores onto a (1,1) named mesh —
    the reshard path a pod-count change exercises."""
    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_local_mesh
    opt = AdamW()
    state = ts.init_train_state(TINY, opt, jax.random.PRNGKey(1))
    ckpt.save(str(tmp_path), 3, state)
    mesh = make_local_mesh()
    shardings = sh.named(mesh, sh.train_state_pspecs(TINY, mesh))
    restored = ckpt.restore(str(tmp_path), state, shardings=shardings)
    leaf = jax.tree_util.tree_leaves(restored.params)[0]
    assert isinstance(leaf.sharding, jax.sharding.NamedSharding)
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_supervisor_resume(tmp_path):
    opt = AdamW(lr=1e-3)
    sup = TrainSupervisor(str(tmp_path), save_every=5, async_save=False)
    init = lambda: ts.init_train_state(TINY, opt, jax.random.PRNGKey(0))
    state, start = sup.restore_or(init)
    assert start == 0
    step = jax.jit(ts.make_train_step(TINY, opt))
    pipe = data_lib.SyntheticLM(TINY, seq_len=16, global_batch=4)
    for i in range(11):
        state, _ = step(state, pipe.batch_at(i))
        sup.maybe_save(i, state)
    # "crash": new supervisor resumes from step 10's checkpoint
    sup2 = TrainSupervisor(str(tmp_path), save_every=5)
    state2, start2 = sup2.restore_or(init)
    assert start2 == 11
    np.testing.assert_array_equal(
        np.asarray(state2.opt.step), np.asarray(state.opt.step))


def test_straggler_monitor():
    mon = StragglerMonitor(window=20, threshold=1.5, min_samples=5)
    flagged = []
    mon.on_straggler = lambda s, t, m: flagged.append(s)
    for i in range(30):
        mon.record(i, 0.1 if i != 25 else 0.9)
    assert flagged == [25]


def test_gradient_compression_error_feedback():
    """bf16-with-error-feedback accumulates to the fp32 mean over steps."""
    g = jnp.full((1000,), 1e-3 + 3e-8, jnp.float32)  # below bf16 resolution
    st = compression.init_state({"g": g})
    total_q = jnp.zeros_like(g)
    state = st
    for _ in range(64):
        q, state = compression.compress_grads({"g": g}, state)
        total_q = total_q + q["g"].astype(jnp.float32)
    # with error feedback the mean quantized grad converges to the truth
    np.testing.assert_allclose(float(total_q.mean()) / 64, float(g[0]),
                               rtol=1e-4)


@pytest.mark.slow
def test_generate_greedy_deterministic():
    cfg = TINY
    prm = Pm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab)
    out1 = serve.generate(cfg, prm, prompts, max_new=6)
    out2 = serve.generate(cfg, prm, prompts, max_new=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # greedy decode must match argmax over the full forward at each step
    toks = jnp.concatenate([prompts, out1], axis=1)
    full, _ = M.forward(cfg, prm, {"tokens": toks})
    for i in range(6):
        want = np.argmax(np.asarray(full[:, 4 + i]), axis=-1)
        np.testing.assert_array_equal(np.asarray(out1[:, i]), want)


def test_data_pipeline_deterministic_and_restartable():
    pipe = data_lib.SyntheticLM(TINY, seq_len=16, global_batch=4, seed=9)
    a = pipe.batch_at(42)
    b = data_lib.SyntheticLM(TINY, seq_len=16, global_batch=4,
                             seed=9).batch_at(42)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = pipe.batch_at(43)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
