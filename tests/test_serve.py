"""The continuous-batching query service: ``Engine.serve``.

The serving contract, end to end: whatever the arrival schedule, lane
count, or chunk size, every served query's output, step count, and
per-channel traffic are bit-identical to a solo run of that query —
lane admission at chunk boundaries reshapes *execution*, never answers.
Solo reference = ``run_batch(prog, pg, [q])`` (Q=1), itself pinned
bit-identical to ``Engine.run`` by tests/test_batch.py.

Covers the fixed regression shapes (a lane refilled mid-flight of its
neighbor, a query halting inside its admission chunk, sessions ending
with unoccupied lanes, budget-exhausted harvests), per-tenancy traffic
accounting on both route_batch strategies, hypothesis-generated arrival
schedules, and cross-process determinism of the serving benchmark's
records. Everything here carries the ``serve`` marker (``-m serve``
selects the serving tier).
"""
import functools
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

import strategies
from repro.algorithms import REGISTRY
from repro.graph import pgraph
from repro.pregel.engine import Engine
from repro.pregel.serve import QueryQueue, ServeResult, poisson_arrivals

pytestmark = pytest.mark.serve

SEED = 0
W = 4
KEY = "reach:basic"   # routed channels — the union-route-sensitive case
CHUNK = 3


@functools.lru_cache(maxsize=None)
def problem(key=KEY):
    spec = REGISTRY[key]
    graph = spec.make_graph(spec.test_scale, SEED)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    prog = spec.factory(**spec.inputs(graph, SEED))
    queries = [int(q) for q in spec.queries(graph, SEED, 8)]
    return graph, pg, prog, queries


@functools.lru_cache(maxsize=None)
def engine(route_batch="union"):
    """One engine per strategy — every test shares its compile cache."""
    return Engine(mode="chunked", chunk_size=CHUNK, route_batch=route_batch)


@functools.lru_cache(maxsize=None)
def solo(key, query, max_steps=None, route_batch="union"):
    """The bit-identity reference: a solo Q=1 run of one query."""
    _, pg, prog, _ = problem(key)
    return engine(route_batch).run_batch(prog, pg, [query],
                                         max_steps=max_steps)


def assert_matches_solo(rec, key=KEY, max_steps=None, route_batch="union"):
    ref = solo(key, rec.query, max_steps, route_batch)
    np.testing.assert_array_equal(np.asarray(rec.output),
                                  np.asarray(ref.outputs[0]))
    assert rec.steps == int(ref.query_steps[0]), rec.qid
    assert rec.halted == bool(ref.query_halted[0]), rec.qid
    assert rec.bytes_by_channel == ref.query_bytes(0), rec.qid
    assert rec.msgs_by_channel == ref.query_msgs(0), rec.qid


def assert_session_invariants(res: ServeResult, n_queries: int):
    """Shape of any completed session: every query served exactly once,
    records in qid order, and the session totals are exactly the sum of
    the per-tenancy attributions (dead/unoccupied lanes add zero)."""
    assert res.num_queries == n_queries
    assert [r.qid for r in res.records] == sorted(r.qid for r in res.records)
    assert len({r.qid for r in res.records}) == n_queries
    for name, total in res.bytes_by_channel.items():
        assert total == sum(r.bytes_by_channel.get(name, 0)
                            for r in res.records), name
    for name, total in res.msgs_by_channel.items():
        assert total == sum(r.msgs_by_channel.get(name, 0)
                            for r in res.records), name
    for rec in res.records:
        assert rec.arrival <= rec.admitted <= rec.finished
        assert rec.latency_steps >= rec.steps


def rb_params():
    """Both route_batch strategies; "lane" rides the slow tier."""
    return [pytest.param("union", id="union"),
            pytest.param("lane", marks=pytest.mark.slow, id="lane")]


# --- schedules -------------------------------------------------------------


@pytest.mark.parametrize("route_batch", rb_params())
def test_all_at_once_schedule_bit_identity(route_batch):
    _, pg, prog, queries = problem()
    res = engine(route_batch).serve(prog, pg, queries, num_lanes=2)
    assert_session_invariants(res, len(queries))
    assert res.dispatches >= len(queries) // 2  # 2 lanes -> forced refills
    for rec in res.records:
        assert_matches_solo(rec, route_batch=route_batch)


def test_trickle_schedule_fast_forwards_idle_lanes():
    _, pg, prog, queries = problem()
    # arrivals far apart: every query runs alone and the clock jumps
    # over the idle gaps instead of spinning dispatches
    schedule = [(50 * i, q) for i, q in enumerate(queries[:4])]
    res = engine().serve(prog, pg, QueryQueue.from_schedule(schedule),
                         num_lanes=2)
    assert_session_invariants(res, 4)
    for rec in res.records:
        assert_matches_solo(rec)
        assert rec.admitted == rec.arrival  # a lane was always free
    assert res.clock >= 150          # the fast-forwards happened
    assert res.supersteps == sum(r.steps for r in res.records)  # no overlap


def test_bursty_schedule():
    _, pg, prog, queries = problem()
    # two bursts that each overflow the lane count -> queueing both times
    schedule = [(0, q) for q in queries[:4]] + [(30, q) for q in queries[4:8]]
    res = engine().serve(prog, pg, QueryQueue.from_schedule(schedule),
                         num_lanes=2)
    assert_session_invariants(res, 8)
    for rec in res.records:
        assert_matches_solo(rec)
    # someone in each burst had to wait for a lane
    assert any(r.admitted > r.arrival for r in res.records)


def test_empty_queue_is_an_empty_session():
    _, pg, prog, _ = problem()
    res = engine().serve(prog, pg, [], num_lanes=2)
    assert res.num_queries == 0 and res.records == []
    assert res.dispatches == 0 and res.supersteps == 0
    assert res.queries_per_s == 0.0
    assert res.latency_summary()["p50_steps"] == 0.0


# --- fixed regression shapes ----------------------------------------------


def test_query_halting_in_its_admission_chunk():
    _, pg, prog, queries = problem()
    # chunk far larger than any query's step count: every query halts in
    # the same dispatch that admitted it, and each boundary harvests the
    # whole wave and admits the next
    res = engine().serve(prog, pg, queries, num_lanes=2, chunk_size=64)
    assert_session_invariants(res, len(queries))
    for rec in res.records:
        assert_matches_solo(rec)
        assert rec.finished - rec.admitted <= 64
    assert res.dispatches == -(-len(queries) // 2)  # one wave per dispatch


def test_lane_refilled_mid_superstep_window():
    _, pg, prog, queries = problem()
    res = engine().serve(prog, pg, queries, num_lanes=2, chunk_size=2)
    assert_session_invariants(res, len(queries))
    for rec in res.records:
        assert_matches_solo(rec)
    # the regression shape must actually occur: some lane was refilled
    # while its neighbor was mid-flight (admitted strictly inside
    # another query's tenancy window)
    assert any(
        a.admitted < b.admitted < a.finished
        for a in res.records for b in res.records
        if a.qid != b.qid and a.lane != b.lane
    ), "no mid-flight refill in this schedule"


def test_session_ending_with_unoccupied_lanes():
    _, pg, prog, queries = problem()
    # 3 lanes, 2 queries: at least one lane is never occupied; 5 queries
    # into 3 lanes also drains to a final dispatch with idle lanes
    for n, lanes in ((2, 3), (5, 3)):
        res = engine().serve(prog, pg, queries[:n], num_lanes=lanes)
        assert_session_invariants(res, n)
        for rec in res.records:
            assert_matches_solo(rec)


def test_budget_exhausted_lanes_are_harvested():
    _, pg, prog, queries = problem()
    ms = 2  # below every query's natural halt -> budget harvests
    res = engine().serve(prog, pg, queries[:4], num_lanes=2, max_steps=ms)
    assert_session_invariants(res, 4)
    for rec in res.records:
        assert rec.steps <= ms
        assert_matches_solo(rec, max_steps=ms)
    assert any(not r.halted for r in res.records)


def test_serve_through_a_fused_engine_and_one_lane():
    _, pg, prog, queries = problem()
    # the engine's own mode is irrelevant: serve always compiles the
    # chunked serving substrate; a single lane degenerates to a serial
    # queue and must still be bit-identical
    eng = Engine(mode="fused")
    res = eng.serve(prog, pg, queries[:3], num_lanes=1, chunk_size=CHUNK)
    assert_session_invariants(res, 3)
    for rec in res.records:
        assert rec.lane == 0
        assert_matches_solo(rec)


# --- traffic accounting ----------------------------------------------------


@pytest.mark.parametrize("route_batch", rb_params())
def test_refilled_lane_counts_only_its_own_tenancy(route_batch):
    _, pg, prog, queries = problem()
    # one lane, three successive tenancies: any traffic inheritance from
    # the previous occupant would inflate the later records above their
    # solo references
    res = engine(route_batch).serve(prog, pg, queries[:3], num_lanes=1,
                                    chunk_size=2)
    assert_session_invariants(res, 3)
    assert all(r.lane == 0 for r in res.records)
    for rec in res.records:
        assert_matches_solo(rec, route_batch=route_batch)
    assert res.records[0].finished <= res.records[1].admitted \
        <= res.records[1].finished <= res.records[2].admitted


@pytest.mark.parametrize("route_batch", rb_params())
def test_session_totals_equal_sum_over_admitted_queries(route_batch):
    _, pg, prog, queries = problem()
    res = engine(route_batch).serve(prog, pg, queries, num_lanes=3)
    assert_session_invariants(res, len(queries))  # includes the totals
    # and the session's wire traffic is exactly the solo runs', summed —
    # unoccupied lanes contributed zero wire slots
    for name, total in res.bytes_by_channel.items():
        assert total == sum(
            solo(KEY, r.query, None, route_batch).query_bytes(0)[name]
            for r in res.records), name


# --- queue / schedule plumbing --------------------------------------------


def test_query_queue_order_and_api():
    q = QueryQueue()
    assert q.push("a", 5) == 0 and q.push("b", 5) == 1 and q.push("c") == 2
    assert len(q) == 3 and q.next_arrival() == 0
    assert q.pop_ready(0).query == "c"
    assert q.pop_ready(0) is None          # nothing else due yet
    assert q.next_arrival() == 5
    first, second = q.pop_ready(5), q.pop_ready(5)
    assert (first.query, second.query) == ("a", "b")  # FIFO tie-break
    with pytest.raises(ValueError):
        q.push("x", -1)


def test_poisson_arrivals_deterministic_and_monotone():
    a = poisson_arrivals(32, rate=0.5, seed=7)
    assert a == poisson_arrivals(32, rate=0.5, seed=7)
    assert a != poisson_arrivals(32, rate=0.5, seed=8)
    assert all(x <= y for x, y in zip(a, a[1:]))
    with pytest.raises(ValueError):
        poisson_arrivals(4, rate=0.0)


def test_program_spec_stream_is_a_schedule():
    graph, _, _, _ = problem()
    spec = REGISTRY[KEY]
    s1 = spec.stream(graph, seed=3, q=6, rate=0.5)
    assert s1 == spec.stream(graph, seed=3, q=6, rate=0.5)
    arrivals = [a for a, _ in s1]
    assert arrivals == sorted(arrivals)
    assert [q for _, q in s1] == list(spec.queries(graph, 3, 6))


def test_serve_rejects_query_less_programs_and_bad_lanes():
    spec = REGISTRY["wcc:basic"]
    graph = spec.make_graph(6, SEED)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    prog = spec.factory()
    with pytest.raises(ValueError, match="query axis"):
        engine().serve(prog, pg, [0])
    _, pg2, prog2, queries = problem()
    with pytest.raises(ValueError, match="lane"):
        engine().serve(prog2, pg2, queries, num_lanes=0)


# --- hypothesis: arbitrary arrival schedules -------------------------------


if strategies.HAVE_HYPOTHESIS:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @pytest.mark.slow
    @settings(max_examples=12, deadline=None)
    @given(data=st.data())
    def test_any_arrival_schedule_is_bit_identical(data):
        _, pg, prog, queries = problem()
        n = data.draw(st.integers(1, 6), label="n_queries")
        arrivals = sorted(data.draw(
            st.lists(st.integers(0, 25), min_size=n, max_size=n),
            label="arrivals"))
        lanes = data.draw(st.integers(1, 3), label="lanes")
        schedule = list(zip(arrivals, queries[:n]))
        res = engine().serve(prog, pg, QueryQueue.from_schedule(schedule),
                             num_lanes=lanes)
        assert_session_invariants(res, n)
        for rec in res.records:
            assert_matches_solo(rec)


# --- cross-process determinism of the benchmark artifact -------------------


_DET_SCRIPT = r'''
import json, sys
from benchmarks import serving
out = serving.run(scale=7, q=6, lanes=2, chunk=2, rate=1.0, seed=0,
                  keys=("reach:basic",))
row = out["programs"]["reach:basic"]
# the deterministic subset: everything except wall-clock measurements
canon = {"records": row["records"],
         "supersteps": row["supersteps_serve"],
         "dispatches": row["dispatches_serve"],
         "p50_steps": row["p50_latency_steps"],
         "p99_steps": row["p99_latency_steps"],
         "headline_q": out["headline"]["q"]}
print("CANON:" + json.dumps(canon, sort_keys=True))
'''


@pytest.mark.slow
def test_serving_benchmark_records_deterministic_across_processes():
    """Same seed + same schedule -> identical record stream (qid, lane,
    admitted, finished, steps, output hash) from two fresh processes:
    lane assignment has no hidden nondeterminism for the committed
    BENCH_serving.json to inherit."""
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(root / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env["JAX_PLATFORMS"] = "cpu"
    outs = []
    for _ in range(2):
        proc = subprocess.run([sys.executable, "-c", _DET_SCRIPT], env=env,
                              capture_output=True, text=True, timeout=900,
                              cwd=str(root))
        assert proc.returncode == 0, f"\n--- stdout:\n{proc.stdout}" \
                                     f"\n--- stderr:\n{proc.stderr}"
        canon = [l for l in proc.stdout.splitlines()
                 if l.startswith("CANON:")]
        assert len(canon) == 1, proc.stdout
        outs.append(json.loads(canon[0][len("CANON:"):]))
    assert outs[0] == outs[1]
    assert len(outs[0]["records"]) == outs[0]["headline_q"]
