"""The resilient execution layer (PR 9).

Pins the three recovery contracts end to end:

  1. Cap-overflow escalation — ``Engine(on_overflow="escalate")`` turns a
     channel-capacity overflow into a bounded re-bucket-and-replay, and
     the recovered run is bit-identical to a run that had enough capacity
     from the start (swept across every registry program with globally
     halved caps).
  2. Checkpoint/resume — a chunked run snapshotted at dispatch
     boundaries and resumed from any snapshot replays the uninterrupted
     run byte for byte: states, step counts, and per-channel traffic.
  3. Serve-lane quarantine — an injected (or real) per-lane failure in a
     serving session takes out exactly that query; every healthy query
     still matches its solo run bit for bit and the failure is reported
     on the session result.

Plus the structured failure taxonomy itself (``repro.pregel.errors``)
across all three execution modes, the int32 traffic-wrap latch, and the
graph/weight input validation that keeps malformed problems from
reaching the runtime at all.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import REGISTRY, sssp
from repro.core import message as msg
from repro.graph import generators as gen, pgraph
from repro.pregel import checkpoint as ckpt_io
from repro.pregel import errors, runtime
from repro.pregel.engine import Engine
from repro.pregel.program import VertexProgram
from repro.pregel.serve import FaultSpec, QueryQueue, as_faults

SEED = 0
W = 4
MODES = ("host", "fused", "chunked")


def _assert_same_output(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# a deterministic overflow-prone program: every vertex messages vertex 0,
# so per-peer traffic ~= n_loc and a small capacity overflows on step 0
# ---------------------------------------------------------------------------

def fanin_program(capacity: int, steps: int = 3) -> VertexProgram:
    def init(pg):
        return {"acc": jnp.zeros((pg.num_workers, pg.n_loc), jnp.float32)}

    def step(ctx, gs, state, i):
        deliv = msg.direct_send(
            ctx, jnp.zeros((ctx.n_loc,), jnp.int32), gs.v_mask,
            {"x": jnp.ones((ctx.n_loc,), jnp.float32)}, capacity=capacity,
            name="fanin")
        got = jnp.where(deliv.mask, deliv.payload["x"], 0.0).sum()
        acc = state["acc"].at[0].add(got)
        return {"acc": acc}, i >= steps - 1, deliv.overflow

    return VertexProgram(
        name="test:fanin", init=init, step=step,
        extract=lambda pg, s: pg.to_global(s["acc"]),
        max_steps=steps + 2)


@functools.lru_cache(maxsize=None)
def small_pg():
    g = gen.rmat(6, edge_factor=4, seed=SEED).symmetrized()
    return pgraph.partition_graph(g, W, "random", build=("raw_out",))


# ---------------------------------------------------------------------------
# failure taxonomy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_overflow_error_is_structured_in_all_modes(mode):
    """Every mode raises ChannelOverflowError (a RuntimeError) carrying
    the superstep, the offending channel names, and the partial result."""
    pg = small_pg()
    prog = fanin_program(capacity=2)
    eng = Engine(mode=mode, chunk_size=2)
    with pytest.raises(errors.ChannelOverflowError,
                       match="capacity overflow") as ei:
        eng.run(prog, pg)
    err = ei.value
    assert isinstance(err, RuntimeError)
    assert err.superstep is not None
    assert "fanin" in err.channels
    assert err.result is not None
    assert err.result.overflow_by_channel["fanin"]


@pytest.mark.parametrize("mode", MODES)
def test_traffic_wrap_raises_in_all_modes(mode):
    """The int32 traffic accumulator wrap is a structured latch in every
    mode, not a silent corruption (fused mode cannot attribute the
    channel — the latch is global there)."""
    pg = small_pg()

    def step(ctx, gs, state, i):
        # 2^31 bytes in one superstep: the int32 stat leaf goes negative
        ctx.add_traffic("big", 2 ** 30, 1)
        ctx.add_traffic("big", 2 ** 30, 1)
        return state, False

    state0 = {"x": jnp.zeros((pg.num_workers, pg.n_loc), jnp.float32)}
    with pytest.raises(errors.TrafficWrapError):
        runtime.run_supersteps(pg, step, state0, max_steps=8, mode=mode,
                               chunk_size=2)


# ---------------------------------------------------------------------------
# overflow escalation
# ---------------------------------------------------------------------------

def test_escalate_recovers_and_matches_unconstrained_run():
    pg = small_pg()
    prog = fanin_program(capacity=2)
    ref = Engine().run(fanin_program(capacity=1024), pg)

    eng = Engine(on_overflow="escalate")
    res = eng.run(prog, pg)
    assert res.recovery, "escalation should have been recorded"
    assert all("fanin" in ev["channels"] or not ev["channels"]
               for ev in res.recovery)
    _assert_same_output(res.output, ref.output)
    assert res.steps == ref.steps
    assert not any(np.asarray(v).any()
                   for v in (res.overflow_by_channel or {}).values())


def test_escalation_is_memoized_per_fingerprint():
    """A second run of the same problem starts at the learned scales —
    no retries, and the executable the escalation compiled is warm."""
    pg = small_pg()
    prog = fanin_program(capacity=2)
    eng = Engine(on_overflow="escalate")
    first = eng.run(prog, pg)
    assert first.recovery
    compiles_after_first = eng.compiles
    second = eng.run(prog, pg)
    assert second.recovery is None
    assert second.cache_hit
    assert eng.compiles == compiles_after_first
    _assert_same_output(first.output, second.output)


def test_escalate_bounded_by_max_retries():
    """A program that overflows no matter the capacity (impossible here,
    so simulate with max_retries=0) still raises, with the recovery
    trail attached to the error's partial result."""
    pg = small_pg()
    prog = fanin_program(capacity=2)
    eng = Engine(on_overflow="escalate", max_retries=0)
    with pytest.raises(errors.ChannelOverflowError):
        eng.run(prog, pg)


@pytest.mark.slow
@pytest.mark.parametrize("key", sorted(REGISTRY))
def test_registry_sweep_halved_caps_escalate_bit_identical(key):
    """Acceptance sweep: every registry program, run with every channel
    capacity halved under ``on_overflow="escalate"``, produces output,
    step count and traffic bit-identical to the untouched run —
    whether or not the halved caps actually overflowed."""
    spec = REGISTRY[key]
    graph = spec.make_graph(6, SEED)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    prog = spec.factory(**spec.inputs(graph, SEED))

    ref = Engine().run(prog, pg)
    res = Engine(cap_scales={"*": 0.5}, on_overflow="escalate").run(prog, pg)
    _assert_same_output(res.output, ref.output)
    assert res.steps == ref.steps
    assert res.bytes_by_channel == ref.bytes_by_channel
    assert res.msgs_by_channel == ref.msgs_by_channel


# ---------------------------------------------------------------------------
# convergence reporting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_converged_flag_mode_parity(mode):
    spec = REGISTRY["wcc:basic"]
    graph = spec.make_graph(6, SEED)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    prog = spec.factory(**spec.inputs(graph, SEED))
    eng = Engine(mode=mode, chunk_size=3)
    assert eng.run(prog, pg).converged
    short = eng.run(prog, pg, max_steps=1)
    assert not short.converged and short.steps == 1


def test_on_nonconverged_policies():
    spec = REGISTRY["wcc:basic"]
    graph = spec.make_graph(6, SEED)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    prog = spec.factory(**spec.inputs(graph, SEED))

    with pytest.raises(errors.NonConvergenceError) as ei:
        Engine(on_nonconverged="raise").run(prog, pg, max_steps=1)
    assert ei.value.result is not None and ei.value.result.steps == 1

    with pytest.warns(RuntimeWarning, match="did not converge"):
        Engine(on_nonconverged="warn").run(prog, pg, max_steps=1)

    # default: silent (pagerank-style fixed-iteration budgets are normal)
    res = Engine().run(prog, pg, max_steps=1)
    assert not res.converged

    with pytest.raises(ValueError):
        Engine(on_nonconverged="explode")
    with pytest.raises(ValueError):
        Engine(on_overflow="retry")


# ---------------------------------------------------------------------------
# checkpoint / resume
# ---------------------------------------------------------------------------

def _wcc_problem():
    spec = REGISTRY["wcc:basic"]
    graph = spec.make_graph(7, SEED)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    return pg, spec.factory(**spec.inputs(graph, SEED))


def test_checkpoint_resume_bit_identical(tmp_path):
    pg, prog = _wcc_problem()
    eng = Engine(mode="chunked", chunk_size=1)
    full = eng.run(prog, pg, checkpoint_every=1,
                   checkpoint_dir=str(tmp_path))
    ckpts = sorted(tmp_path.glob("*.ckpt"))
    assert len(ckpts) >= 2, "run too short to exercise resume"

    for path in ckpts:                # resume from every mid-run snapshot
        ck = ckpt_io.load(str(path))
        resumed = Engine(mode="chunked", chunk_size=1).run(
            prog, pg, resume=ck)
        assert resumed.resumed_from == ck.step
        assert resumed.steps == full.steps
        assert resumed.halted == full.halted
        assert resumed.converged == full.converged
        assert resumed.bytes_by_channel == full.bytes_by_channel
        assert resumed.msgs_by_channel == full.msgs_by_channel
        _assert_same_output(resumed.output, full.output)
        _assert_same_output(resumed.state, full.state)


def test_checkpoint_resume_from_path_and_latest(tmp_path):
    pg, prog = _wcc_problem()
    eng = Engine(mode="chunked", chunk_size=2)
    full = eng.run(prog, pg, checkpoint_every=2,
                   checkpoint_dir=str(tmp_path))
    newest = ckpt_io.latest(str(tmp_path))
    assert newest is not None
    resumed = Engine(mode="chunked", chunk_size=2).run(
        prog, pg, resume=newest)
    _assert_same_output(resumed.output, full.output)
    assert resumed.steps == full.steps


def test_checkpoint_validation_rejects_mismatches(tmp_path):
    pg, prog = _wcc_problem()
    Engine(mode="chunked", chunk_size=2).run(
        prog, pg, checkpoint_every=2, checkpoint_dir=str(tmp_path))
    path = ckpt_io.latest(str(tmp_path))
    ck = ckpt_io.load(path)

    other = fanin_program(capacity=1024)
    with pytest.raises(ValueError, match="program"):
        Engine(mode="chunked").run(other, small_pg(), resume=ck)
    with pytest.raises(ValueError, match="max_steps"):
        Engine(mode="chunked", chunk_size=2).run(
            prog, pg, max_steps=ck.max_steps + 1, resume=ck)
    with pytest.raises(ValueError, match="graph signature"):
        g2 = gen.rmat(6, edge_factor=4, seed=SEED + 3).symmetrized()
        pg2 = pgraph.partition_graph(g2, W, "random",
                                     build=REGISTRY["wcc:basic"].build)
        Engine(mode="chunked", chunk_size=2).run(prog, pg2, resume=ck)


def test_checkpoint_requires_chunked_and_dir(tmp_path):
    pg, prog = _wcc_problem()
    with pytest.raises(ValueError, match="chunked"):
        Engine(mode="fused").run(prog, pg, checkpoint_every=2,
                                 checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="checkpoint_dir"):
        Engine(mode="chunked").run(prog, pg, checkpoint_every=2)


# ---------------------------------------------------------------------------
# serve-lane quarantine + fault injection
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def serve_problem():
    spec = REGISTRY["reach:basic"]
    graph = spec.make_graph(spec.test_scale, SEED)
    pg = pgraph.partition_graph(graph, W, "random", build=spec.build)
    prog = spec.factory(**spec.inputs(graph, SEED))
    queries = [int(q) for q in spec.queries(graph, SEED, 8)]
    return pg, prog, queries


def test_serve_fault_injection_isolates_failures():
    pg, prog, queries = serve_problem()
    eng = Engine(mode="chunked", chunk_size=3)
    faults = [FaultSpec(qid=2, at_step=1, kind="overflow"),
              (5, 2, "exhaust")]
    res = eng.serve(prog, pg, queries, num_lanes=3, faults=faults)

    assert res.num_queries == len(queries)
    assert res.failed_qids == [2]
    by_qid = {r.qid: r for r in res.records}
    bad = by_qid[2]
    assert bad.status == "overflow" and bad.injected
    assert bad.output is None and not bad.halted
    ex = by_qid[5]
    assert ex.status == "exhausted" and ex.injected
    assert ex.output is not None and not ex.halted
    assert ex.steps >= 2

    # every un-faulted query is bit-identical to its solo run
    for rec in res.records:
        if rec.qid in (2, 5):
            continue
        solo = eng.run_batch(prog, pg, [rec.query])
        assert rec.status == "ok" and not rec.injected
        np.testing.assert_array_equal(np.asarray(rec.output),
                                      np.asarray(solo.outputs[0]))
        assert rec.steps == int(solo.query_steps[0])
        assert rec.bytes_by_channel == solo.query_bytes(0)
        assert rec.msgs_by_channel == solo.query_msgs(0)

    # session totals still equal the sum of per-record attributions
    for name, total in res.bytes_by_channel.items():
        assert total == sum(r.bytes_by_channel.get(name, 0)
                            for r in res.records), name


def test_serve_on_fault_raise_reports_qids():
    pg, prog, queries = serve_problem()
    eng = Engine(mode="chunked", chunk_size=3)
    with pytest.raises(errors.ChannelOverflowError) as ei:
        eng.serve(prog, pg, queries, num_lanes=3,
                  faults=[FaultSpec(qid=1, at_step=0)], on_fault="raise")
    assert list(ei.value.qids) == [1]


def test_serve_quarantined_lane_is_recycled():
    """A quarantined lane must keep serving later arrivals — the failed
    tenancy never leaks into the next occupant's answer."""
    pg, prog, queries = serve_problem()
    eng = Engine(mode="chunked", chunk_size=3)
    res = eng.serve(prog, pg, queries, num_lanes=2,
                    faults=[FaultSpec(qid=0, at_step=0)])
    assert res.failed_qids == [0]
    served_ok = [r for r in res.records if r.status != "overflow"]
    assert len(served_ok) == len(queries) - 1
    for rec in served_ok:
        solo = eng.run_batch(prog, pg, [rec.query])
        np.testing.assert_array_equal(np.asarray(rec.output),
                                      np.asarray(solo.outputs[0]))


def test_serve_straggler_monitor_reports():
    pg, prog, queries = serve_problem()
    res = Engine(mode="chunked", chunk_size=3).serve(
        prog, pg, queries, num_lanes=3)
    assert isinstance(res.straggler_dispatches, list)
    assert res.dispatch_median_s >= 0.0


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(qid=0, at_step=0, kind="meteor")
    with pytest.raises(ValueError, match="at_step"):
        FaultSpec(qid=0, at_step=-1)
    with pytest.raises(ValueError, match="duplicate"):
        as_faults([(0, 1, "overflow"), (0, 2, "exhaust")])
    with pytest.raises(ValueError, match="on_fault"):
        pg, prog, queries = serve_problem()
        Engine(mode="chunked").serve(prog, pg, queries, on_fault="panic")


# ---------------------------------------------------------------------------
# input validation
# ---------------------------------------------------------------------------

def test_partition_rejects_out_of_range_endpoints():
    g = gen.EdgeList(n=8, edges=np.array([[0, 1], [2, 9]], np.int64))
    with pytest.raises(ValueError, match="outside"):
        pgraph.partition_graph(g, 2, "random", build=("raw_out",))
    g2 = gen.EdgeList(n=8, edges=np.array([[0, 1], [-1, 2]], np.int64))
    with pytest.raises(ValueError, match="outside"):
        pgraph.partition_graph(g2, 2, "random", build=("raw_out",))


def test_partition_rejects_nonfinite_weights():
    edges = np.array([[0, 1], [1, 2]], np.int64)
    for bad in (np.nan, np.inf):
        g = gen.EdgeList(n=4, edges=edges,
                         weights=np.array([1.0, bad], np.float32))
        with pytest.raises(ValueError, match="non-finite"):
            pgraph.partition_graph(g, 2, "random", build=("raw_out",))


def test_sssp_rejects_negative_weights():
    edges = np.array([[0, 1], [1, 2], [2, 3]], np.int64)
    g = gen.EdgeList(n=4, edges=edges,
                     weights=np.array([1.0, -2.0, 3.0], np.float32))
    pg = pgraph.partition_graph(g, 2, "random",
                                build=("raw_out", "prop_out"))
    for variant in sssp.VARIANTS:
        prog = sssp.program(variant=variant, source=0)
        with pytest.raises(ValueError, match="non-negative"):
            prog.init(pg)
        with pytest.raises(ValueError, match="non-negative"):
            prog.query_init(pg, 0)
