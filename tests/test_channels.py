"""Channel-level correctness: routing, request-respond, combined message,
aggregator — vs brute-force numpy delivery, including hypothesis property
tests over random message sets (shared instance space:
tests/strategies.py)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

import strategies
from strategies import N_LOC, W, random_scalar_messages
from repro.core import aggregator as agg
from repro.core import message as msg
from repro.core import request_respond as rr
from repro.core.channel import ChannelContext

AXIS = "w"


def run_sharded(fn, *args):
    """vmap a per-shard fn with the worker axis name."""
    return jax.vmap(fn, axis_name=AXIS)(*args)


def make_ctx():
    return ChannelContext(AXIS, W, N_LOC)


def np_deliver(dst, valid, vals):
    """Brute-force: for each worker, list of (dst, val) delivered to it."""
    out = [[] for _ in range(W)]
    for w in range(W):
        for i in range(dst.shape[1]):
            if valid[w, i]:
                owner = dst[w, i] // N_LOC
                out[owner].append((dst[w, i] % N_LOC, vals[w, i]))
    return out


@settings(max_examples=20, deadline=None)
@given(seed=strategies.seeds, m=st.integers(1, 40))
def test_combined_send_matches_bruteforce(seed, m):
    dst, valid, vals = random_scalar_messages(seed, m)

    def shard(d, v, x):
        ctx = make_ctx()
        out, got, ovf = msg.combined_send(ctx, d, v, x, "sum", capacity=m)
        return out, got, ovf

    out, got, ovf = run_sharded(shard, jnp.array(dst), jnp.array(valid),
                                jnp.array(vals))
    assert not bool(np.asarray(ovf).any())
    expect = np.zeros((W, N_LOC), np.float64)
    expect_got = np.zeros((W, N_LOC), bool)
    for w, deliv in enumerate(np_deliver(dst, valid, vals)):
        for lidx, v in deliv:
            expect[w, lidx] += v
            expect_got[w, lidx] = True
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got), expect_got)


@settings(max_examples=20, deadline=None)
@given(seed=strategies.seeds)
def test_request_respond_matches_gather(seed):
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, W * N_LOC, (W, N_LOC)).astype(np.int32)
    valid = rng.random((W, N_LOC)) < 0.8
    attr = rng.normal(size=(W, N_LOC)).astype(np.float32)

    def shard(d, v, a):
        ctx = make_ctx()
        out, ovf = rr.request(ctx, d, v, a, capacity=N_LOC)
        return out, ovf

    out, ovf = run_sharded(shard, jnp.array(dst), jnp.array(valid),
                           jnp.array(attr))
    assert not bool(np.asarray(ovf).any())
    flat_attr = attr.reshape(-1)
    expect = np.where(valid, flat_attr[dst], 0.0)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5)


def test_request_respond_dedup_traffic():
    """All requests to ONE vertex => exactly one remote request per worker."""
    dst = np.full((W, N_LOC), 0, np.int32)  # everyone asks vertex 0 (worker 0)
    valid = np.ones((W, N_LOC), bool)
    attr = np.arange(W * N_LOC, dtype=np.float32).reshape(W, N_LOC)

    def shard(d, v, a):
        ctx = make_ctx()
        out, _ = rr.request(ctx, d, v, a, capacity=N_LOC)
        return out, ctx.stats_msgs["request_respond/request"]

    out, nreq = run_sharded(shard, jnp.array(dst), jnp.array(valid),
                            jnp.array(attr))
    # workers 1..3 send exactly 1 deduped request each; worker 0 sends 0
    np.testing.assert_array_equal(np.sort(np.asarray(nreq)), [0, 1, 1, 1])
    np.testing.assert_allclose(np.asarray(out), np.full((W, N_LOC), attr[0, 0]))


def test_direct_send_capacity_overflow_flag():
    dst = np.zeros((W, 8), np.int32)  # everything to vertex 0
    valid = np.ones((W, 8), bool)

    def shard(d, v):
        ctx = make_ctx()
        deliv = msg.direct_send(ctx, d, v, {"x": jnp.zeros(8)}, capacity=4)
        return deliv.overflow

    ovf = run_sharded(shard, jnp.array(dst), jnp.array(valid))
    assert bool(np.asarray(ovf).any())


@pytest.mark.parametrize("comb,expect", [
    ("sum", 8 * W), ("min", 1.0), ("max", 2.0),
])
def test_aggregator(comb, expect):
    vals = np.full((W, N_LOC), 1.0, np.float32)
    vals[:, 0] = 2.0  # sum rows: 2 + 15*... make simple: mask half
    valid = np.zeros((W, N_LOC), bool)
    valid[:, :8] = True
    vals[:, 1:] = 1.0

    def shard(x, v):
        ctx = make_ctx()
        return agg.aggregate(ctx, x, comb, valid=v)

    out = run_sharded(jax.jit(shard), jnp.array(vals), jnp.array(valid))
    # masked: per worker 8 valid entries: one 2.0 and seven 1.0
    if comb == "sum":
        expect = W * (2.0 + 7.0)
    np.testing.assert_allclose(np.asarray(out), expect)


def test_scatter_combine_no_ids_on_wire():
    """Scatter-combine traffic must be payload-only (no id bytes)."""
    from repro.graph import generators as gen, pgraph
    from repro.core import scatter_combine as sc

    g = gen.rmat(7, edge_factor=4, seed=0)
    pg = pgraph.partition_graph(g, W, "random", build=("scatter_out",))

    def shard(plan, vals):
        ctx = ChannelContext(AXIS, W, pg.n_loc)
        out = sc.broadcast_combine(ctx, plan, vals, "sum")
        return out, ctx.stats_bytes["scatter_combine"], ctx.stats_msgs["scatter_combine"]

    vals = jnp.ones((W, pg.n_loc), jnp.float32)
    out, nbytes, nmsgs = jax.vmap(shard, axis_name=AXIS)(pg.scatter_out, vals)
    assert int(np.asarray(nbytes).sum()) == 4 * int(np.asarray(nmsgs).sum())
    # every vertex receives its (in-degree restricted to dedup'd workers)...
    # sanity: total received equals total edges when vals == 1
    total = float(np.asarray(out).sum())
    assert total == pg.scatter_out.total_edges
