"""The kernelized sparse data plane: one-pass bucket routing vs the
sort-route baseline (bit-identical ``Routed`` contract), the Pallas
bucket-rank kernel vs its jnp oracle, wire-message traffic accounting
(post-dedup, capacity-clamped), the density-adaptive exchange, the
batched union-frontier route pass vs Q per-lane passes (per-lane
``Routed`` contract, halted-lane masking, lane-varying-dst fallback),
and the ``use_kernel``/``route_impl``/``route_batch`` configuration
surface end to end (env var -> Engine knob -> RunResult)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import strategies
from strategies import N_LOC, W, random_messages
from repro.core import compose
from repro.core import message as msg
from repro.core import routing
from repro.core.channel import ChannelContext
from repro.kernels import ops as kops
from repro.kernels import ref as kref

AXIS = "w"
MODES = ("host", "fused", "chunked")


def make_ctx():
    return ChannelContext(AXIS, W, N_LOC)


def run_sharded(fn, *args):
    return jax.vmap(fn, axis_name=AXIS)(*args)


def _route_fields(impl, dst, valid, payload, capacity):
    def shard(d, v, p):
        routed = routing.route(make_ctx(), d, v, p, capacity, impl=impl)
        return (routed.ids, routed.mask, routed.payload, routed.slot,
                routed.sent_count, routed.overflow)

    return run_sharded(shard, dst, valid, payload)


def _assert_bit_identical(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# bucket-route vs sort-route parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,m,cap", [(0, 40, 40), (1, 64, 64), (2, 7, 7)])
def test_bucket_matches_sort_bit_identical(seed, m, cap):
    dst, valid, payload = random_messages(seed, m)
    _assert_bit_identical(
        _route_fields("bucket", dst, valid, payload, cap),
        _route_fields("sort", dst, valid, payload, cap),
    )


def test_bucket_matches_sort_edge_cases():
    m = 16
    zero_pay = {"x": jnp.zeros((W, m), jnp.float32)}
    # empty: no valid message anywhere
    dst = jnp.zeros((W, m), jnp.int32)
    none = jnp.zeros((W, m), bool)
    a = _route_fields("bucket", dst, none, zero_pay, m)
    b = _route_fields("sort", dst, none, zero_pay, m)
    _assert_bit_identical(a, b)
    assert not np.asarray(a[5]).any()          # no overflow
    assert int(np.asarray(a[4]).sum()) == 0    # no wire messages
    # all messages to one owner (vertex 0's worker), full valid
    all_valid = jnp.ones((W, m), bool)
    _assert_bit_identical(
        _route_fields("bucket", dst, all_valid, zero_pay, m),
        _route_fields("sort", dst, all_valid, zero_pay, m),
    )


def test_overflow_latch_equivalence_and_wire_clamp():
    """Capacity overflow: both impls latch the flag, and both charge only
    the messages that fit on the wire (capacity-clamped sent_count) —
    never the enqueued overflow."""
    m, cap = 16, 3
    dst = jnp.zeros((W, m), jnp.int32)  # everyone floods vertex 0
    valid = jnp.ones((W, m), bool)
    for impl in ("bucket", "sort"):
        ids, mask, _, slot, sent, ovf = _route_fields(
            impl, dst, valid, {}, cap)
        assert np.asarray(ovf).all(), impl
        np.testing.assert_array_equal(
            np.asarray(sent), np.tile(np.eye(W, dtype=np.int32)[0] * cap, (W, 1))
        )
        # exactly cap messages packed per worker, the rest dropped
        assert int((np.asarray(slot) < W * cap).sum()) == W * cap


def test_route_impl_env_and_scope(monkeypatch):
    monkeypatch.delenv("REPRO_ROUTE_IMPL", raising=False)
    assert routing.resolve_impl() == "bucket"
    monkeypatch.setenv("REPRO_ROUTE_IMPL", "sort")
    assert routing.resolve_impl() == "sort"
    with routing.impl_scope("bucket"):
        assert routing.resolve_impl() == "bucket"  # scope beats env
    assert routing.resolve_impl() == "sort"
    with pytest.raises(ValueError, match="unknown routing impl"):
        routing.resolve_impl("warp")


def test_route_batch_env_and_scope(monkeypatch):
    monkeypatch.delenv("REPRO_ROUTE_BATCH", raising=False)
    assert routing.resolve_batch() == "union"
    monkeypatch.setenv("REPRO_ROUTE_BATCH", "lane")
    assert routing.resolve_batch() == "lane"
    with routing.batch_scope("union"):
        assert routing.resolve_batch() == "union"  # scope beats env
    assert routing.resolve_batch() == "lane"
    assert routing.resolve_batch("union") == "union"  # explicit beats env
    with pytest.raises(ValueError, match="unknown route batch strategy"):
        routing.resolve_batch("fleet")


# ---------------------------------------------------------------------------
# batched routing: one union-frontier pass vs Q per-lane serial routes
# ---------------------------------------------------------------------------

NQ = 3


def _route_union_fields(dst, valid_l, payload_l, capacity, live):
    """Per-lane ``Routed`` views of the shared union-frontier pass.

    Reproduces the runtime's nesting: worker vmap (axis name) outside, a
    query vmap inside, with per-lane batched ``query_index``/``query_live``
    scalars on the context. ``dst`` (W, M) is lane-invariant; ``valid_l``
    and the payload leaves carry a (W, NQ, M, ...) lane axis."""
    nq = valid_l.shape[1]
    qidx = jnp.arange(nq, dtype=jnp.int32)
    live = jnp.asarray(live, bool)

    def shard(d, v, p):
        def lane(qi, vi, pi, lvi):
            ctx = ChannelContext(AXIS, W, N_LOC, query_index=qi,
                                 query_live=lvi, num_queries=nq)
            r = routing.route_union(ctx, d, vi, pi, capacity)
            return (r.ids, r.mask, r.payload, r.slot, r.sent_count,
                    r.overflow)

        return jax.vmap(lane)(qidx, v, p, live)

    return run_sharded(shard, dst, valid_l, payload_l)


def _serial_lane_fields(dst, valid_l, payload_l, capacity, live):
    """Q independent serial route passes — the reference the per-lane
    union views must reproduce (halted lanes route nothing)."""
    out = []
    for ql in range(valid_l.shape[1]):
        v = valid_l[:, ql] & bool(live[ql])
        p = jax.tree_util.tree_map(lambda a: a[:, ql], payload_l)
        out.append(_route_fields("bucket", dst, v, p, capacity))
    return out


def _block_rows(ids_c, mask_c, pay_slices):
    """Sorted (id, payload...) rows of one (receiver, sender) wire block —
    the union pass reorders slots within a block but must deliver exactly
    the serial multiset."""
    keep = np.asarray(mask_c)
    cols = [np.asarray(ids_c)[keep].reshape(-1, 1).astype(np.float64)]
    for leaf in pay_slices:
        a = np.asarray(leaf)[keep].astype(np.float64)
        cols.append(a.reshape(a.shape[0],
                              int(np.prod(a.shape[1:], dtype=np.int64))))
    mat = np.concatenate(cols, axis=1)
    return mat[np.lexsort(mat.T[::-1])]


def _assert_union_matches_serial(union, serial, capacity, dst):
    """The per-lane contract of the shared pass vs Q serial routes:

      - ``sent_count`` is exact (per-lane per-peer wire occupancy);
      - ``overflow`` is a conservative latch (union ranks dominate lane
        ranks): it never misses a serial overflow;
      - wherever the sending lane did not overflow, each (receiver,
        sender) block delivers the exact serial multiset of
        (id, payload) rows, and the sender-side slots place packed
        messages in the destination owner's block."""
    u_ids, u_mask, u_pay, u_slot, u_sent, u_ovf = union
    u_pay_leaves = jax.tree_util.tree_leaves(u_pay)
    nq = u_mask.shape[1]
    for ql in range(nq):
        s_ids, s_mask, s_pay, s_slot, s_sent, s_ovf = serial[ql]
        s_pay_leaves = jax.tree_util.tree_leaves(s_pay)
        np.testing.assert_array_equal(
            np.asarray(u_sent[:, ql]), np.asarray(s_sent))
        so = np.asarray(s_ovf)
        uo = np.asarray(u_ovf[:, ql])
        assert np.all(uo >= so), "union overflow missed a serial overflow"
        # sender-side slot contract: a packed slot lands in the block of
        # the destination's owner, and absent overflow the packed set is
        # exactly the serial one
        sl = np.asarray(u_slot[:, ql])
        packed = sl < W * capacity
        owner = np.clip(np.asarray(dst) // N_LOC, 0, W - 1)
        np.testing.assert_array_equal(
            (sl // capacity)[packed], owner[packed])
        for w in range(W):
            if not uo[w]:
                np.testing.assert_array_equal(
                    packed[w], np.asarray(s_slot[w]) < W * capacity)
        for wrecv in range(W):
            for wsend in range(W):
                if uo[wsend]:
                    continue  # drops differ under overflow; sets don't align
                got = _block_rows(
                    u_ids[wrecv, ql, wsend], u_mask[wrecv, ql, wsend],
                    [lf[wrecv, ql, wsend] for lf in u_pay_leaves])
                want = _block_rows(
                    s_ids[wrecv, wsend], s_mask[wrecv, wsend],
                    [lf[wrecv, wsend] for lf in s_pay_leaves])
                np.testing.assert_array_equal(got, want)


def _lane_instance(seed, m, nq=NQ, valid_frac=0.7):
    rng = np.random.default_rng(seed)
    dst = jnp.asarray(rng.integers(0, W * N_LOC, (W, m)).astype(np.int32))
    valid_l = jnp.asarray(rng.random((W, nq, m)) < valid_frac)
    payload_l = {
        "f": jnp.asarray(rng.normal(size=(W, nq, m)).astype(np.float32)),
        "i2": jnp.asarray(
            rng.integers(-9, 9, (W, nq, m, 2)).astype(np.int32)),
    }
    return dst, valid_l, payload_l


@pytest.mark.parametrize("case", ("plain", "overflow", "empty_lane",
                                  "halted_lane", "disjoint"))
def test_route_union_matches_per_lane(case):
    m = 24
    dst, valid_l, payload_l = _lane_instance(5, m)
    live = [True] * NQ
    cap = m
    if case == "overflow":
        cap = 3
    elif case == "empty_lane":
        valid_l = valid_l.at[:, 1].set(False)
    elif case == "halted_lane":
        live = [True, False, True]
    elif case == "disjoint":
        lane_of = jnp.arange(m) % NQ
        valid_l = valid_l & (lane_of[None, None, :] ==
                             jnp.arange(NQ)[None, :, None])
    union = _route_union_fields(dst, valid_l, payload_l, cap, live)
    serial = _serial_lane_fields(dst, valid_l, payload_l, cap, live)
    _assert_union_matches_serial(union, serial, cap, dst)


def test_route_union_halted_lane_cannot_pollute_the_wire():
    """The pad/halt fix: a halted lane's (stale, garbage) frontier must
    not reach the union — the live lanes' shared views are bit-identical
    to a run where that lane simply has nothing to send, and the halted
    lane's own view is empty."""
    m = 20
    dst, valid_l, payload_l = _lane_instance(9, m)
    stale = valid_l.at[:, 2].set(True)        # lane 2: full garbage frontier
    a = _route_union_fields(dst, stale, payload_l, m, [True, True, False])
    quiet = valid_l.at[:, 2].set(False)       # lane 2: genuinely empty
    b = _route_union_fields(dst, quiet, payload_l, m, [True, True, True])
    _assert_bit_identical(a, b)
    _, mask_a, _, _, sent_a, ovf_a = a
    assert int(np.asarray(sent_a)[:, 2].sum()) == 0
    assert not np.asarray(mask_a)[:, 2].any()
    assert not np.asarray(ovf_a)[:, 2].any()


def test_route_union_lane_varying_dst_falls_back_bit_identical():
    """A per-lane ``dst`` makes positional slot sharing unsound; the
    custom_vmap rule proves it via in_batched and runs Q serial passes —
    bit-identical to the per-lane reference, positions included."""
    m = 18
    rng = np.random.default_rng(13)
    dst_l = jnp.asarray(
        rng.integers(0, W * N_LOC, (W, NQ, m)).astype(np.int32))
    _, valid_l, payload_l = _lane_instance(13, m)
    nq = NQ
    qidx = jnp.arange(nq, dtype=jnp.int32)
    live = jnp.ones((nq,), bool)

    def shard(d, v, p):
        def lane(qi, di, vi, pi, lvi):
            ctx = ChannelContext(AXIS, W, N_LOC, query_index=qi,
                                 query_live=lvi, num_queries=nq)
            r = routing.route_union(ctx, di, vi, pi, m)
            return (r.ids, r.mask, r.payload, r.slot, r.sent_count,
                    r.overflow)

        return jax.vmap(lane)(qidx, d, v, p, live)

    union = run_sharded(shard, dst_l, valid_l, payload_l)
    for ql in range(nq):
        p = jax.tree_util.tree_map(lambda a: a[:, ql], payload_l)
        serial = _route_fields("bucket", dst_l[:, ql], valid_l[:, ql], p, m)
        _assert_bit_identical(
            jax.tree_util.tree_map(lambda a: a[:, ql], union), serial)


# ---------------------------------------------------------------------------
# hypothesis property tests (optional-import, PR 1 convention; shared
# instance space from tests/strategies.py)
# ---------------------------------------------------------------------------

if strategies.HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        seed=strategies.seeds,
        m=strategies.message_counts,
        cap_frac=st.floats(0.1, 1.0),
        valid_frac=strategies.fractions,
    )
    def test_route_parity_property(seed, m, cap_frac, valid_frac):
        """Random messages, random capacity (including overflowing ones):
        every Routed field is bit-identical across the two impls."""
        dst, valid, payload = random_messages(seed, m, valid_frac=valid_frac)
        cap = max(1, int(m * cap_frac))
        _assert_bit_identical(
            _route_fields("bucket", dst, valid, payload, cap),
            _route_fields("sort", dst, valid, payload, cap),
        )

    @settings(max_examples=15, deadline=None)
    @given(
        seed=strategies.seeds,
        m=st.integers(1, 48),
        cap_frac=st.floats(0.1, 1.0),
        valid_frac=strategies.fractions,
        live_bits=st.integers(0, 2 ** NQ - 1),
    )
    def test_route_union_parity_property(seed, m, cap_frac, valid_frac,
                                         live_bits):
        """Random lanes, random capacity (overflowing ones included),
        random halt pattern: every per-lane view of the union pass
        reproduces the serial per-lane contract — exact sent counts,
        conservative overflow, exact delivered multisets where the lane
        did not overflow, and empty views for halted lanes."""
        dst, valid_l, payload_l = _lane_instance(seed, m,
                                                 valid_frac=valid_frac)
        live = [bool((live_bits >> i) & 1) for i in range(NQ)]
        cap = max(1, int(m * cap_frac))
        union = _route_union_fields(dst, valid_l, payload_l, cap, live)
        serial = _serial_lane_fields(dst, valid_l, payload_l, cap, live)
        _assert_union_matches_serial(union, serial, cap, dst)

    @settings(max_examples=25, deadline=None)
    @given(seed=strategies.seeds, m=st.integers(1, 400),
           b=st.integers(1, 16))
    def test_bucket_ranks_kernel_property(seed, m, b):
        rng = np.random.default_rng(seed)
        keys = jnp.asarray(rng.integers(0, b + 1, m).astype(np.int32))
        rk, ck = kops.bucket_ranks(keys, b, use_kernel=True, block_msgs=64)
        rr, cr = kref.bucket_ranks_ref(keys, b)
        np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))


# ---------------------------------------------------------------------------
# bucket-rank kernel vs oracle (fixed cases; property sweep above)
# ---------------------------------------------------------------------------


def test_bucket_ranks_kernel_matches_ref():
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.integers(0, W + 1, 1000).astype(np.int32))
    rk, ck = kops.bucket_ranks(keys, W, use_kernel=True, block_msgs=128)
    rr, cr = kref.bucket_ranks_ref(keys, W)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))


def test_route_kernel_path_matches_reference():
    """route(impl='bucket') with the Pallas kernel (interpret) ==
    the jnp reference, under vmap like the real runtime."""
    dst, valid, payload = random_messages(7, 48)

    def shard(use_kernel):
        def fn(d, v, p):
            routed = routing.route(make_ctx(), d, v, p, 48,
                                   impl="bucket", use_kernel=use_kernel)
            return (routed.ids, routed.mask, routed.payload, routed.slot,
                    routed.sent_count, routed.overflow)
        return run_sharded(fn, dst, valid, payload)

    _assert_bit_identical(shard(True), shard(False))


# ---------------------------------------------------------------------------
# precomputed chunk plans (the ScatterPlan autotune path)
# ---------------------------------------------------------------------------


def test_plan_chunks_mirrors_kernel_padding():
    """ops.plan_chunks builds host tables against the kernel's padded
    view; if the two paddings ever desynchronize the kernel combines the
    wrong chunks. Sweep block sizes that force max_chunks > 1."""
    rng = np.random.default_rng(21)
    n, e = 100, 1500
    seg_np = np.sort(rng.integers(0, n, e)).astype(np.int32)
    vals = jnp.asarray(rng.normal(size=(e, 2)).astype(np.float32))
    want = kref.segment_combine_ref(vals, jnp.asarray(seg_np), n, "sum")
    for br, be in [(8, 64), (32, 128), (128, 512)]:
        cs, nc, mx = kops.plan_chunks(seg_np, n, br, be)
        assert mx >= 1
        got = kops.segment_combine(
            vals, jnp.asarray(seg_np), n, "sum", use_kernel=True,
            assume_sorted=True, block_rows=br, block_edges=be,
            chunk_plan=(jnp.asarray(cs), jnp.asarray(nc), mx))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_scatter_plan_chunk_tables_drive_the_kernel():
    """The default-on-TPU path: segment_combine through a built
    ScatterPlan's autotuned chunk tables == the reference, per worker."""
    from repro.graph import generators as gen, pgraph

    g = gen.rmat(8, edge_factor=8, seed=7).symmetrized()
    pg = pgraph.partition_graph(g, W, "random", build=("scatter_out",))
    plan = pg.scatter_out
    rng = np.random.default_rng(8)
    for w in range(W):
        seg = plan.edge_seg[w]
        vals = jnp.asarray(rng.normal(size=(plan.e_cap, 1)).astype(np.float32))
        want = kref.segment_combine_ref(vals, seg, plan.u_cap, "min")
        got = kops.segment_combine(
            vals, seg, plan.u_cap, "min", use_kernel=True,
            assume_sorted=True, block_rows=plan.block_rows,
            block_edges=plan.block_edges,
            chunk_plan=(plan.chunk_start[w], plan.chunk_count[w],
                        plan.max_chunks))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# traffic accounting: id bytes per wire message, post-dedup
# ---------------------------------------------------------------------------


def test_combined_send_charges_post_dedup_wire_messages():
    """Heavy duplication: the id bytes ride the deduped wire messages,
    not the enqueued sends."""
    rng = np.random.default_rng(11)
    m = 64
    dst = rng.integers(0, 8, (W, m)).astype(np.int32)  # few hot targets
    valid = rng.random((W, m)) < 0.8
    vals = rng.normal(size=(W, m)).astype(np.float32)

    def shard(d, v, x):
        ctx = make_ctx()
        msg.combined_send(ctx, d, v, x, "sum", capacity=m)
        return ctx.stats_msgs["combined_message"], ctx.stats_bytes["combined_message"]

    nm, nb = run_sharded(shard, jnp.asarray(dst), jnp.asarray(valid),
                         jnp.asarray(vals))
    for w in range(W):
        unique_remote = len({
            int(dst[w, i]) for i in range(m) if valid[w, i]
            and dst[w, i] // N_LOC != w
        })
        assert int(np.asarray(nm)[w]) == unique_remote
        assert int(np.asarray(nb)[w]) == unique_remote * (4 + 4)


@pytest.mark.slow
def test_composed_bytes_under_sums_equal_total():
    """Regression (accounting fix): per-component namespaced sums still
    reconstruct the run total exactly, on both routing impls."""
    from repro.algorithms import sv
    from repro.graph import generators as gen, pgraph

    g = gen.rmat(7, edge_factor=4, seed=3).symmetrized()
    pg = pgraph.partition_graph(
        g, W, "random", build=("scatter_out", "raw_out"))
    for impl in ("bucket", "sort"):
        with routing.impl_scope(impl):
            _, res = sv.run(pg, variant="composed")
        chan = sv.composed_channels()
        per_component = sum(
            res.bytes_under(f"sv/{key}") for key in chan.components)
        assert per_component == res.total_bytes
        per_msgs = sum(
            res.msgs_under(f"sv/{key}") for key in chan.components)
        assert per_msgs == res.total_msgs


# ---------------------------------------------------------------------------
# data plane on/off: mode parity and cross-impl bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("impl", ("bucket", "sort"))
def test_mode_parity_with_dataplane_on_and_off(impl):
    """fused/chunked/host stay bit-identical (states, steps, stats) with
    the new data plane on (bucket) and off (sort) — and the two impls are
    bit-identical to each other."""
    from repro.algorithms import sv
    from repro.graph import generators as gen, pgraph

    g = gen.rmat(7, edge_factor=4, seed=5).symmetrized()
    pg = pgraph.partition_graph(
        g, W, "random", build=("scatter_out", "raw_out"))
    results = {}
    for mode in MODES:
        lab, res = sv.run(pg, variant="both", mode=mode, chunk_size=3,
                          route_impl=impl)
        results[mode] = (lab, res)
        assert res.route_impl == impl
    ref_lab, ref_res = results["host"]
    for mode in ("fused", "chunked"):
        lab, res = results[mode]
        np.testing.assert_array_equal(ref_lab, lab)
        assert res.steps == ref_res.steps
        assert res.bytes_by_channel == ref_res.bytes_by_channel
        assert res.msgs_by_channel == ref_res.msgs_by_channel
    # stash for the cross-impl comparison below
    _CROSS_IMPL[impl] = (ref_lab, ref_res.bytes_by_channel)


_CROSS_IMPL = {}


@pytest.mark.slow
def test_cross_impl_bit_identity():
    if {"bucket", "sort"} <= set(_CROSS_IMPL):
        lab_b, bytes_b = _CROSS_IMPL["bucket"]
        lab_s, bytes_s = _CROSS_IMPL["sort"]
        np.testing.assert_array_equal(lab_b, lab_s)
        assert bytes_b == bytes_s


# ---------------------------------------------------------------------------
# density-adaptive exchange
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("threshold,expect_dense,expect_sparse",
                         [(0.0, True, False), (1.1, False, True)])
def test_density_adaptive_combine_extremes(threshold, expect_dense,
                                           expect_sparse):
    """Forced thresholds on the wcc switch: only the chosen plane's
    traffic is accounted and labels never change."""
    from repro.algorithms import wcc
    from repro.graph import generators as gen, pgraph

    g = gen.rmat(7, edge_factor=4, seed=1).symmetrized()
    pg = pgraph.partition_graph(
        g, W, "random", build=("scatter_out", "raw_out"))
    lab_basic, _ = wcc.run(pg, variant="basic")
    lab, res = wcc.run(pg, variant="switch", dense_threshold=threshold)
    np.testing.assert_array_equal(lab_basic, lab)
    assert (res.bytes_under("wcc/dense") > 0) == expect_dense
    assert (res.bytes_under("wcc/sparse") > 0) == expect_sparse


# ---------------------------------------------------------------------------
# configuration surface: env var -> Engine knob -> RunResult
# ---------------------------------------------------------------------------


def test_use_kernel_env_and_scope(monkeypatch):
    monkeypatch.delenv("REPRO_USE_KERNEL", raising=False)
    assert kops.resolve_use_kernel() == (jax.default_backend() == "tpu")
    monkeypatch.setenv("REPRO_USE_KERNEL", "1")
    assert kops.resolve_use_kernel()
    monkeypatch.setenv("REPRO_USE_KERNEL", "off")
    assert not kops.resolve_use_kernel()
    with kops.use_kernel_scope(True):
        assert kops.resolve_use_kernel()     # scope beats env
        assert not kops.resolve_use_kernel(False)  # explicit beats scope


def test_engine_knobs_reach_run_result():
    from repro.algorithms import get_program
    from repro.graph import generators as gen, pgraph
    from repro.pregel.engine import Engine

    spec_g = gen.rmat(7, edge_factor=4, seed=0).symmetrized()
    pg = pgraph.partition_graph(spec_g, W, "random", build=("raw_out",))
    prog = get_program("wcc:basic")
    eng = Engine(route_impl="sort", use_kernel=False)
    res = eng.run(prog, pg)
    assert res.route_impl == "sort" and res.use_kernel is False
    # same engine, same graph: cached; a different data plane is a
    # different engine and a fresh compile
    eng2 = Engine(route_impl="bucket", use_kernel=False)
    res2 = eng2.run(prog, pg)
    assert res2.route_impl == "bucket"
    assert eng.compiles == 1 and eng2.compiles == 1
    np.testing.assert_array_equal(res.output, res2.output)
    assert res.bytes_by_channel == res2.bytes_by_channel
