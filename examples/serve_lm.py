"""Serving example: batched prefill + token-by-token decode with KV cache
(greedy and sampled), on a reduced mixtral-family config — exercising SWA
ring caches and MoE routing in the decode path.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import params as Pm
from repro.serve import decode as serve


def main():
    cfg = registry.ARCHS["mixtral-8x7b"].smoke
    print(f"serving {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"{cfg.moe_experts} experts top-{cfg.moe_top_k} "
          f"window={cfg.attn_window}")
    params = Pm.init_params(cfg, jax.random.PRNGKey(0))

    batch, prompt_len, max_new = 4, 12, 16
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab)

    t0 = time.perf_counter()
    out = serve.generate(cfg, params, prompts, max_new=max_new)
    t1 = time.perf_counter()
    print(f"greedy: {batch} requests x {max_new} new tokens "
          f"in {t1-t0:.2f}s ({batch*max_new/(t1-t0):.1f} tok/s)")
    print("  completions:", np.asarray(out)[:, :8].tolist())

    out_s = serve.generate(cfg, params, prompts, max_new=max_new,
                           temperature=0.8, seed=3)
    print("  sampled:    ", np.asarray(out_s)[:, :8].tolist())

    # throughput sweep over batch sizes (continuous-batching capacity probe)
    for b in (1, 8, 32):
        p = jax.random.randint(jax.random.PRNGKey(2), (b, prompt_len),
                               0, cfg.vocab)
        t0 = time.perf_counter()
        serve.generate(cfg, params, p, max_new=8)
        dt = time.perf_counter() - t0
        print(f"  batch {b:3d}: {b*8/dt:8.1f} tok/s")


if __name__ == "__main__":
    main()
