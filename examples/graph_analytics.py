"""End-to-end driver: connected components of a power-law graph with the
fully-composed S-V algorithm (request-respond + scatter-combine +
combined-message + full-jumping channels, stacked via
``repro.core.compose`` — docs/composition.md), compared across channel
compositions and verified against a host union-find oracle.

All programs come from the registry (``repro.algorithms.REGISTRY``) and
run through ONE compile-once ``Engine`` session (docs/programs.md) —
the per-variant wall times below therefore pay trace+compile exactly
once per program, the way a long-lived analytics service would.

    PYTHONPATH=src python examples/graph_analytics.py \
        [--scale 13] [--workers 8] [--mode fused]
"""
import argparse

import numpy as np

from repro.algorithms import get_program
from repro.graph import generators as gen, pgraph
from repro.pregel.engine import Engine


def canon(x):
    first = {}
    return np.array([first.setdefault(v, i) for i, v in enumerate(x)])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--mode", default="fused",
                    choices=("host", "fused", "chunked"))
    ap.add_argument("--chunk-size", type=int, default=16)
    args = ap.parse_args()

    print(f"generating R-MAT scale {args.scale} "
          f"(n={1 << args.scale}) undirected ...")
    g = gen.rmat(args.scale, edge_factor=8, seed=7).symmetrized()
    print(f"  n={g.n} edges={g.num_edges}")

    pg = pgraph.partition_graph(
        g, args.workers, "random",
        build=("scatter_out", "prop_out", "raw_out"))
    truth = canon(gen.components_ground_truth(g))
    n_comp = len(set(truth.tolist()))
    print(f"  {n_comp} components (oracle)\n")

    eng = Engine(mode=args.mode, chunk_size=args.chunk_size)
    print(f"{'program':26s} {'runtime':>9s} {'traffic':>12s} "
          f"{'supersteps':>10s}  correct")
    res_composed = None
    for variant in ("basic", "reqresp", "scatter", "both", "composed"):
        res = eng.run(get_program(f"sv:{variant}"), pg)
        if variant == "composed":
            res_composed = res
        ok = bool((canon(res.output) == truth).all())
        print(f"S-V ({variant:9s})          {res.wall_time_s:8.2f}s "
              f"{res.total_bytes/1e6:10.3f} MB {res.steps:10d}  {ok}")

    res = eng.run(get_program("wcc:prop"), pg)
    ok = bool((canon(res.output) == truth).all())
    print(f"WCC (propagation)          {res.wall_time_s:8.2f}s "
          f"{res.total_bytes/1e6:10.3f} MB {res.steps:10d}  {ok}")

    # a second composed run through the same session: zero compiles
    warm = eng.run(get_program("sv:composed"), pg)
    assert warm.cache_hit
    print(f"\nwarm composed re-run       {warm.wall_time_s:8.2f}s "
          f"(cache hit; session {eng.stats()})")

    print("\ncomposed S-V per-component bytes:")
    for key in ("pointer", "neighbor_min", "merge", "jump"):
        print(f"  sv/{key:13s} {res_composed.bytes_under(f'sv/{key}'):10d}")
    print("\nThe composed S-V uses the fewest rounds and the least "
          "traffic — the paper's headline result.")


if __name__ == "__main__":
    main()
