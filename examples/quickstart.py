"""Quickstart: the channel interface in 60 lines.

Implements PageRank two ways — the standard CombinedMessage channel and
the optimized ScatterCombine channel — exactly the one-line optimization
switch the paper demonstrates (§III-B), and prints the traffic
difference. The superstep loop runs under the fused on-device runtime by
default; pass --mode host|fused|chunked to compare (docs/runtime.md).
This example drives the raw runtime to show the step contract; for the
declarative VertexProgram / compile-once Engine / registry layer on top
of it, see docs/programs.md and examples/graph_analytics.py.

    PYTHONPATH=src python examples/quickstart.py [--scale 12] [--mode fused]
"""
import argparse

import jax.numpy as jnp

from repro.core import aggregator as agg
from repro.core import message as msg
from repro.core import scatter_combine as sc
from repro.graph import generators as gen, pgraph
from repro.pregel import runtime


def pagerank_step(graph, variant):
    def step(ctx, g, state, step_idx):
        pr = state["pr"]
        deg = jnp.maximum(g.deg_out, 1).astype(jnp.float32)
        contrib = jnp.where(g.deg_out > 0, pr / deg, 0.0)

        if variant == "scatter":                # the optimized channel
            incoming = sc.broadcast_combine(ctx, g.scatter_out, contrib, "sum")
        else:                                   # the standard channel
            raw = g.raw_out
            incoming, _, _ = msg.combined_send(
                ctx, raw.dst_global, raw.mask, contrib[raw.src_local],
                "sum", capacity=ctx.n_loc)

        sink = agg.aggregate(                    # the aggregator channel
            ctx, jnp.where((g.deg_out == 0) & g.v_mask, pr, 0.0), "sum")
        n = jnp.float32(graph.n)
        new_pr = jnp.where(g.v_mask,
                           0.15 / n + 0.85 * (incoming + sink / n), 0.0)
        return {"pr": new_pr}, step_idx >= 19
    return step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--mode", default="fused",
                    choices=("host", "fused", "chunked"))
    ap.add_argument("--chunk-size", type=int, default=8)
    args = ap.parse_args()

    graph = gen.rmat(args.scale, edge_factor=8, seed=1)
    pg = pgraph.partition_graph(graph, n_workers=8, partitioner="random",
                                build=("scatter_out", "raw_out"))
    state0 = {"pr": jnp.where(pg.v_mask, 1.0 / graph.n, 0.0)}

    for variant in ("basic", "scatter"):
        res = runtime.run_supersteps(pg, pagerank_step(graph, variant),
                                     state0, max_steps=20, mode=args.mode,
                                     chunk_size=args.chunk_size)
        pr = pg.to_global(res.state["pr"])
        print(f"PageRank [{variant:7s}] sum={pr.sum():.6f} "
              f"supersteps={res.steps} "
              f"traffic={res.total_bytes/1e6:.3f} MB "
              f"({res.total_msgs} messages) "
              f"mode={res.mode} dispatches={res.dispatches}")
    print("\nSwitching one channel changed the traffic, not the algorithm.")


if __name__ == "__main__":
    main()
