"""End-to-end LM training driver: trains a ~100M-parameter mamba2-family
model for a few hundred steps on CPU with checkpointing enabled, via the
production launcher.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(The driver is `repro.launch.train`; this example pins a 100M-ish config.
For the full assigned architectures use --arch <id> without --hundred-m.)
"""
import argparse
import dataclasses
import sys

import jax

from repro.configs import registry
from repro.distributed.fault_tolerance import StragglerMonitor, TrainSupervisor
from repro.train import data as data_lib
from repro.train import train_step as ts
from repro.train.optimizer import AdamW


def hundred_m_config():
    """~100M params: a scaled mamba2 (fast per-token on CPU, real stack)."""
    base = registry.ARCHS["mamba2-130m"].config
    return dataclasses.replace(
        base, name="mamba2-100m-example", n_layers=12, d_model=512,
        vocab=32000, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_config()
    opt = AdamW(lr=1e-3, warmup_steps=50)
    pipe = data_lib.SyntheticLM(cfg, args.seq_len, args.global_batch)
    step = jax.jit(ts.make_train_step(cfg, opt, microbatches=2, remat=True),
                   donate_argnums=(0,))

    sup = TrainSupervisor(args.ckpt_dir, save_every=100)
    state, start = sup.restore_or(
        lambda: ts.init_train_state(cfg, opt, jax.random.PRNGKey(0)))
    mon = StragglerMonitor()

    n = sum(x.size for x in jax.tree_util.tree_leaves(state.params))
    print(f"{cfg.name}: {n/1e6:.1f}M params | "
          f"{args.global_batch}x{args.seq_len} tok/step | resume at {start}")

    import time
    for i in range(start, args.steps):
        t0 = time.perf_counter()
        state, m = step(state, pipe.batch_at(i))
        loss = float(m["loss"])
        mon.record(i, time.perf_counter() - t0)
        sup.maybe_save(i, state)
        if i % 20 == 0:
            print(f"  step {i:4d}  loss {loss:7.4f}  "
                  f"({mon.median*1e3:.0f} ms/step median)")
    sup.finalize(args.steps - 1, state)
    print(f"done: final loss {loss:.4f}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
